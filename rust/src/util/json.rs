//! Minimal JSON substrate: parser + writer.
//!
//! Only what the repo needs — parsing `artifacts/manifest.json`, writing
//! experiment records, and (since the serving layer) decoding request
//! bodies — implemented from scratch because no serde is available in
//! the offline registry.
//!
//! Because `bcrun serve` feeds this parser bytes straight off the
//! network, it is hardened against untrusted input:
//!
//! * nesting is capped at [`MAX_DEPTH`] (the recursive-descent parser
//!   would otherwise stack-overflow on `[[[[...`);
//! * numbers that overflow f64 (`1e999`) are parse errors, so a parsed
//!   tree never holds non-finite values (and the writer emits `null`
//!   for any non-finite number constructed programmatically, keeping
//!   output valid JSON);
//! * [`Json::parse_untrusted`] additionally caps the input size;
//! * a mutilation property test pins "errors, never panics".

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Deepest accepted array/object nesting — recursion is bounded by this,
/// so adversarial `[[[[...` input errors out instead of overflowing the
/// stack.
pub const MAX_DEPTH: usize = 128;

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        Ok(v)
    }

    /// [`Json::parse`] with an input-size cap in front — the entry point
    /// for network-supplied bytes (the depth cap applies to every parse).
    pub fn parse_untrusted(s: &str, max_bytes: usize) -> Result<Json, String> {
        if s.len() > max_bytes {
            return Err(format!("input of {} bytes exceeds cap {max_bytes}", s.len()));
        }
        Json::parse(s)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (deterministic key order — Obj is a BTreeMap).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literal; emit null so the
                    // output always reparses (the parser never produces
                    // non-finite numbers itself)
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    /// Current array/object nesting (bounded by [`MAX_DEPTH`]).
    depth: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at offset {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at offset {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        let v: f64 = s.parse().map_err(|e| format!("bad number '{s}': {e}"))?;
        if !v.is_finite() {
            return Err(format!("number '{s}' overflows f64"));
        }
        Ok(Json::Num(v))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|e| e.to_string())?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn enter(&mut self) -> Result<(), String> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} at offset {}", self.i));
        }
        Ok(())
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        self.enter()?;
        let mut v = vec![];
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at offset {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        self.enter()?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at offset {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"x":[1,2.5,"s",null,true],"y":{"z":-3}}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn depth_is_capped_but_reasonable_nesting_parses() {
        // 100 deep: fine
        let ok = format!("{}1{}", "[".repeat(100), "]".repeat(100));
        assert!(Json::parse(&ok).is_ok());
        // 100k deep: must be a clean error, not a stack overflow
        let deep_arr = "[".repeat(100_000);
        let err = Json::parse(&deep_arr).unwrap_err();
        assert!(err.contains("nesting"), "{err}");
        let deep_obj = "{\"a\":".repeat(100_000);
        let err = Json::parse(&deep_obj).unwrap_err();
        assert!(err.contains("nesting"), "{err}");
    }

    #[test]
    fn untrusted_parse_caps_input_size() {
        assert!(Json::parse_untrusted("[1,2,3]", 1024).is_ok());
        let err = Json::parse_untrusted("[1,2,3]", 3).unwrap_err();
        assert!(err.contains("exceeds cap"), "{err}");
    }

    #[test]
    fn overflowing_numbers_are_errors_and_nonfinite_writes_null() {
        // the parser never produces non-finite numbers...
        assert!(Json::parse("1e999").unwrap_err().contains("overflows"));
        assert!(Json::parse("-1e999").is_err());
        // ...and programmatic non-finite numbers serialize as null, so
        // writer output always reparses
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let s = Json::Arr(vec![Json::Num(v)]).to_string();
            assert_eq!(s, "[null]");
            assert!(Json::parse(&s).is_ok());
        }
    }

    #[test]
    fn parse_never_panics_on_mutilated_input_and_roundtrips_when_ok() {
        // fuzz-style: truncate / flip / insert bytes over valid docs (the
        // server feeds this parser raw network bytes). The property: the
        // parser returns, and anything it accepts reserializes to
        // something it accepts again, equal to the first parse.
        use crate::prop;
        let bases: [&str; 5] = [
            r#"{"x":[1.5,-2,3e4],"s":"a\nb\u0041c","n":null,"t":[true,false]}"#,
            r#"[[[[1],2],"\u12zq"],{},{"k":{"v":[-0.0,1e-3]}}]"#,
            r#"{"a":{"b":[1,2,{"c":"d e f"}],"q":"\\\"\t"}}"#,
            "-1.25e-3",
            r#""lone string with \u0000 and tail""#,
        ];
        let interesting: &[u8] = b"\"\\{}[]:,0123456789eE+-.utrfn celsn\x00\x1f\x7f\xff";
        prop::check(
            "json parse is total on mutilated input",
            |rng| {
                let mut bytes = bases[rng.below(bases.len())].as_bytes().to_vec();
                for _ in 0..1 + rng.below(8) {
                    if bytes.is_empty() {
                        break;
                    }
                    match rng.below(3) {
                        0 => bytes.truncate(rng.below(bytes.len() + 1)),
                        1 => {
                            let at = rng.below(bytes.len());
                            bytes[at] = interesting[rng.below(interesting.len())];
                        }
                        _ => {
                            let at = rng.below(bytes.len() + 1);
                            bytes.insert(at, interesting[rng.below(interesting.len())]);
                        }
                    }
                }
                String::from_utf8_lossy(&bytes).into_owned()
            },
            |s| {
                if let Ok(v) = Json::parse(s) {
                    let again = Json::parse(&v.to_string())
                        .map_err(|e| format!("reserialized form failed to parse: {e}"))?;
                    if again != v {
                        return Err("reserialize/reparse changed the value".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn parses_real_manifest_if_present() {
        if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
            let j = Json::parse(&text).unwrap();
            assert!(j.get("models").is_some());
        }
    }
}
