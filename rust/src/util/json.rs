//! Minimal JSON substrate: parser + writer.
//!
//! Only what the repo needs — parsing `artifacts/manifest.json` and writing
//! experiment records — implemented from scratch because no serde is
//! available in the offline registry. Strict enough for machine-generated
//! JSON; not a general-purpose validator.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (deterministic key order — Obj is a BTreeMap).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at offset {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at offset {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|e| e.to_string())?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = vec![];
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at offset {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at offset {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"x":[1,2.5,"s",null,true],"y":{"z":-3}}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, again);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parses_real_manifest_if_present() {
        if let Ok(text) = std::fs::read_to_string("artifacts/manifest.json") {
            let j = Json::parse(&text).unwrap();
            assert!(j.get("models").is_some());
        }
    }
}
