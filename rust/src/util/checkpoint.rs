//! Versioned training checkpoints (`BCCKPT01`): crash-safe save/resume
//! for the coordinator.
//!
//! A checkpoint captures everything the trainer needs to continue a run
//! *bit-exactly* from an epoch boundary: the full [`TrainState`] (params
//! plus the Adam/Nesterov `m`/`v` slots), the root RNG stream state, the
//! epoch/step counters, the best-model trackers, and the learning curves
//! so far. Hyperparameters are pinned by an explicit (model, mode, opt,
//! seed, epochs) tuple plus a CRC fingerprint of the remaining knobs —
//! resuming under a different configuration is a hard error, because the
//! replayed stream would silently diverge from the uninterrupted run.
//!
//! Writes follow the `.bcpack` crash-safe discipline (binary/export.rs):
//! serialize → CRC32 trailer → same-directory temp file → fsync → atomic
//! rename. Loads verify the CRC before parsing and sanity-cap every size
//! field before allocating, so a torn or corrupt file is a clean error —
//! and [`latest_good`] falls back to the previous good checkpoint.

use std::path::{Path, PathBuf};

use crate::runtime::TrainState;
use crate::util::crc32;
use crate::util::error::{Context, Result};
use crate::util::FaultPlan;
use crate::{bail, ensure};

pub const MAGIC: &[u8; 8] = b"BCCKPT01";
const EXT: &str = "bcckpt";

/// Caps for load-time validation: reject corrupt headers before they can
/// request absurd allocations.
const MAX_NAME_BYTES: usize = 256;
const MAX_CURVES: usize = 1 << 20;
const MAX_FILE_BYTES: u64 = 1 << 31;

/// One epoch row of the learning curve, as persisted in a checkpoint.
/// Mirrors `coordinator::EpochRecord` (kept separate so util/ does not
/// depend on coordinator/).
#[derive(Clone, Copy, Debug)]
pub struct CurvePoint {
    pub epoch: u32,
    pub lr: f32,
    pub train_loss: f64,
    pub train_err: f64,
    pub val_err: f64,
    pub seconds: f64,
}

/// A full trainer snapshot at an epoch boundary.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// model name (must match the executor's spec on resume)
    pub model: String,
    /// `Mode as u8` / `Opt as u8` of the run that wrote this
    pub mode: u8,
    pub opt: u8,
    /// root trainer seed and total epoch target of the run
    pub seed: u64,
    pub total_epochs: u32,
    /// CRC32 fingerprint over the remaining hyperparameters
    /// (`TrainOpts::hyper_fingerprint`)
    pub hyper_fp: u32,
    /// the next epoch to run (== number of completed epochs)
    pub epoch_next: u32,
    /// global step counter after the last completed epoch
    pub step: u32,
    /// root RNG (xoshiro256++) state at the boundary
    pub rng: [u64; 4],
    /// best-model trackers (early stopping / Table-1 protocol)
    pub best_val: f64,
    pub best_epoch: u32,
    pub test_at_best: f64,
    pub stale: u32,
    /// lifetime divergence-sentinel counter
    pub diverged_steps: u64,
    /// learning curve of the completed epochs (len == epoch_next)
    pub curves: Vec<CurvePoint>,
    /// params + optimizer slots
    pub state: TrainState,
}

/// Canonical file name for the checkpoint taken after `epoch_next`
/// completed epochs; lexicographic order == epoch order.
pub fn epoch_path(dir: &Path, epoch_next: u32) -> PathBuf {
    dir.join(format!("ckpt-{epoch_next:06}.{EXT}"))
}

fn serialize(ck: &Checkpoint) -> Vec<u8> {
    let mut buf: Vec<u8> = Vec::new();
    buf.extend_from_slice(MAGIC);
    let name = ck.model.as_bytes();
    buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
    buf.extend_from_slice(name);
    buf.push(ck.mode);
    buf.push(ck.opt);
    buf.extend_from_slice(&ck.seed.to_le_bytes());
    buf.extend_from_slice(&ck.total_epochs.to_le_bytes());
    buf.extend_from_slice(&ck.hyper_fp.to_le_bytes());
    buf.extend_from_slice(&ck.epoch_next.to_le_bytes());
    buf.extend_from_slice(&ck.step.to_le_bytes());
    for w in ck.rng {
        buf.extend_from_slice(&w.to_le_bytes());
    }
    buf.extend_from_slice(&ck.best_val.to_bits().to_le_bytes());
    buf.extend_from_slice(&ck.best_epoch.to_le_bytes());
    buf.extend_from_slice(&ck.test_at_best.to_bits().to_le_bytes());
    buf.extend_from_slice(&ck.stale.to_le_bytes());
    buf.extend_from_slice(&ck.diverged_steps.to_le_bytes());
    buf.extend_from_slice(&(ck.curves.len() as u32).to_le_bytes());
    for c in &ck.curves {
        buf.extend_from_slice(&c.epoch.to_le_bytes());
        buf.extend_from_slice(&c.lr.to_bits().to_le_bytes());
        for f in [c.train_loss, c.train_err, c.val_err, c.seconds] {
            buf.extend_from_slice(&f.to_bits().to_le_bytes());
        }
    }
    ck.state.serialize_into(&mut buf);
    let crc = crc32(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());
    buf
}

/// Write `ck` to `path` crash-safely (temp + fsync + atomic rename, CRC
/// trailer). With a [`FaultPlan`] carrying `torn_checkpoint@P`, a fired
/// decision truncates the serialized bytes before the write — producing
/// exactly the torn-medium artifact the CRC gate must reject at load.
pub fn save(ck: &Checkpoint, path: &Path, faults: Option<&FaultPlan>) -> Result<()> {
    let mut buf = serialize(ck);
    if faults.is_some_and(|f| f.roll_torn_checkpoint()) {
        buf.truncate(buf.len() * 2 / 3);
    }

    // same-directory temp so the rename cannot cross a filesystem
    let dir = path.parent().filter(|d| !d.as_os_str().is_empty());
    let name = path
        .file_name()
        .and_then(|n| n.to_str())
        .with_context(|| format!("{}: not a writable file path", path.display()))?;
    let tmp_name = format!(".{name}.tmp.{}", std::process::id());
    let tmp = match dir {
        Some(d) => d.join(&tmp_name),
        None => Path::new(&tmp_name).to_path_buf(),
    };
    let write = (|| -> Result<()> {
        use std::io::Write;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&buf)?;
        f.sync_all()?; // data durable before the rename publishes it
        Ok(())
    })();
    if let Err(e) = write {
        let _ = std::fs::remove_file(&tmp);
        return Err(e).with_context(|| format!("write {}", tmp.display()));
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e.into());
    }
    // best effort: make the rename itself durable
    #[cfg(unix)]
    if let Some(d) = dir {
        if let Ok(dirf) = std::fs::File::open(d) {
            let _ = dirf.sync_all();
        }
    }
    Ok(())
}

/// Load and fully validate one checkpoint file: CRC before parsing,
/// size caps before allocating, no trailing bytes, sane RNG state.
/// Model/hyperparameter compatibility is the *caller's* check (the
/// trainer knows the current run's configuration).
pub fn load(path: &Path) -> Result<Checkpoint> {
    let meta = std::fs::metadata(path).with_context(|| format!("open {}", path.display()))?;
    if meta.len() > MAX_FILE_BYTES {
        bail!("{}: {} bytes exceeds the {MAX_FILE_BYTES} byte cap", path.display(), meta.len());
    }
    let bytes = std::fs::read(path).with_context(|| format!("read {}", path.display()))?;
    // magic(8) + name_len(4) + crc(4) is the smallest conceivable file
    if bytes.len() < 16 {
        bail!("{}: {} bytes is too short to be a BCCKPT file", path.display(), bytes.len());
    }
    if bytes[..8] != MAGIC[..] {
        bail!("{}: not a BCCKPT checkpoint", path.display());
    }
    let (body, trailer) = bytes.split_at(bytes.len() - 4);
    let stored = u32::from_le_bytes([trailer[0], trailer[1], trailer[2], trailer[3]]);
    let computed = crc32(body);
    if stored != computed {
        bail!(
            "{}: checksum mismatch (torn write or corruption): \
             stored {stored:#010x}, computed {computed:#010x}",
            path.display()
        );
    }
    let mut r: &[u8] = &body[8..];
    let name_len = take_u32(&mut r, path, "name length")? as usize;
    ensure!(name_len <= MAX_NAME_BYTES, "{}: implausible model-name length {name_len}", path.display());
    ensure!(r.len() >= name_len, "{}: truncated model name", path.display());
    let model = std::str::from_utf8(&r[..name_len])
        .with_context(|| format!("{}: model name is not UTF-8", path.display()))?
        .to_string();
    r = &r[name_len..];
    let mode = take_u8(&mut r, path, "mode")?;
    let opt = take_u8(&mut r, path, "opt")?;
    ensure!(mode <= 2 && opt <= 2, "{}: invalid mode/opt bytes {mode}/{opt}", path.display());
    let seed = take_u64(&mut r, path, "seed")?;
    let total_epochs = take_u32(&mut r, path, "total epochs")?;
    let hyper_fp = take_u32(&mut r, path, "hyper fingerprint")?;
    let epoch_next = take_u32(&mut r, path, "epoch counter")?;
    let step = take_u32(&mut r, path, "step counter")?;
    let mut rng = [0u64; 4];
    for w in &mut rng {
        *w = take_u64(&mut r, path, "rng state")?;
    }
    ensure!(
        rng.iter().any(|&w| w != 0),
        "{}: all-zero RNG state (corrupt capture)",
        path.display()
    );
    let best_val = f64::from_bits(take_u64(&mut r, path, "best val")?);
    let best_epoch = take_u32(&mut r, path, "best epoch")?;
    let test_at_best = f64::from_bits(take_u64(&mut r, path, "test at best")?);
    let stale = take_u32(&mut r, path, "stale counter")?;
    let diverged_steps = take_u64(&mut r, path, "diverged counter")?;
    let n_curves = take_u32(&mut r, path, "curve count")? as usize;
    ensure!(n_curves <= MAX_CURVES, "{}: implausible curve count {n_curves}", path.display());
    ensure!(
        n_curves == epoch_next as usize,
        "{}: curve count {n_curves} does not match epoch counter {epoch_next}",
        path.display()
    );
    ensure!(
        r.len() >= n_curves * 40,
        "{}: truncated learning curve",
        path.display()
    );
    let mut curves = Vec::with_capacity(n_curves);
    for _ in 0..n_curves {
        let epoch = take_u32(&mut r, path, "curve epoch")?;
        let lr = f32::from_bits(take_u32(&mut r, path, "curve lr")?);
        let train_loss = f64::from_bits(take_u64(&mut r, path, "curve loss")?);
        let train_err = f64::from_bits(take_u64(&mut r, path, "curve err")?);
        let val_err = f64::from_bits(take_u64(&mut r, path, "curve val")?);
        let seconds = f64::from_bits(take_u64(&mut r, path, "curve secs")?);
        curves.push(CurvePoint { epoch, lr, train_loss, train_err, val_err, seconds });
    }
    let state = TrainState::deserialize(&mut r)
        .with_context(|| format!("parse {}", path.display()))?;
    if !r.is_empty() {
        bail!("{}: {} trailing bytes after the state", path.display(), r.len());
    }
    Ok(Checkpoint {
        model,
        mode,
        opt,
        seed,
        total_epochs,
        hyper_fp,
        epoch_next,
        step,
        rng,
        best_val,
        best_epoch,
        test_at_best,
        stale,
        diverged_steps,
        curves,
        state,
    })
}

/// All checkpoint files in `dir`, sorted ascending by name (== by
/// epoch). A missing directory is just "no checkpoints".
pub fn list(dir: &Path) -> Vec<PathBuf> {
    let Ok(entries) = std::fs::read_dir(dir) else {
        return vec![];
    };
    let mut files: Vec<PathBuf> = entries
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.extension().and_then(|e| e.to_str()) == Some(EXT)
                && p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("ckpt-"))
        })
        .collect();
    files.sort();
    files
}

/// Save `ck` under its canonical name in `dir` (creating the directory),
/// then prune all but the newest `keep` checkpoints (`keep == 0` keeps
/// everything).
pub fn save_into_dir(
    dir: &Path,
    ck: &Checkpoint,
    keep: usize,
    faults: Option<&FaultPlan>,
) -> Result<PathBuf> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("create checkpoint dir {}", dir.display()))?;
    let path = epoch_path(dir, ck.epoch_next);
    save(ck, &path, faults)?;
    if keep > 0 {
        let files = list(dir);
        if files.len() > keep {
            for old in &files[..files.len() - keep] {
                let _ = std::fs::remove_file(old);
            }
        }
    }
    Ok(path)
}

/// The newest checkpoint in `dir` that loads and validates, skipping
/// (with a note on stderr) any newer files that turn out to be torn or
/// corrupt — the fallback path of the crash-safety contract. `None` when
/// the directory is missing, empty, or holds no loadable checkpoint.
pub fn latest_good(dir: &Path) -> Option<(PathBuf, Checkpoint)> {
    for path in list(dir).into_iter().rev() {
        match load(&path) {
            Ok(ck) => return Some((path, ck)),
            Err(e) => {
                eprintln!("checkpoint: skipping {}: {e}", path.display());
            }
        }
    }
    None
}

fn take_u8(r: &mut &[u8], path: &Path, what: &str) -> Result<u8> {
    if r.is_empty() {
        bail!("{}: truncated before {what}", path.display());
    }
    let v = r[0];
    *r = &r[1..];
    Ok(v)
}

fn take_u32(r: &mut &[u8], path: &Path, what: &str) -> Result<u32> {
    if r.len() < 4 {
        bail!("{}: truncated before {what}", path.display());
    }
    let mut b = [0u8; 4];
    b.copy_from_slice(&r[..4]);
    *r = &r[4..];
    Ok(u32::from_le_bytes(b))
}

fn take_u64(r: &mut &[u8], path: &Path, what: &str) -> Result<u64> {
    if r.len() < 8 {
        bail!("{}: truncated before {what}", path.display());
    }
    let mut b = [0u8; 8];
    b.copy_from_slice(&r[..8]);
    *r = &r[8..];
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("bc_ckpt_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn toy(epoch_next: u32) -> Checkpoint {
        Checkpoint {
            model: "toy".to_string(),
            mode: 1,
            opt: 2,
            seed: 42,
            total_epochs: 9,
            hyper_fp: 0xDEAD_BEEF,
            epoch_next,
            step: epoch_next * 7,
            rng: [1, 2, 3, epoch_next as u64 + 4],
            best_val: 0.25,
            best_epoch: epoch_next.saturating_sub(1),
            test_at_best: f64::NAN, // pre-first-eval sentinel must survive
            stale: 1,
            diverged_steps: 3,
            curves: (0..epoch_next)
                .map(|e| CurvePoint {
                    epoch: e,
                    lr: 0.01 / (e + 1) as f32,
                    train_loss: 0.5,
                    train_err: 0.1,
                    val_err: 0.2,
                    seconds: 0.0,
                })
                .collect(),
            state: TrainState {
                params: vec![vec![1.0, -0.0, f32::NAN], vec![2.5]],
                m: vec![vec![0.1, 0.2, 0.3], vec![f32::INFINITY]],
                v: vec![vec![1e-9, 0.0, -4.0], vec![0.5]],
            },
        }
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let dir = tmpdir("rt");
        let ck = toy(3);
        let path = epoch_path(&dir, 3);
        save(&ck, &path, None).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.model, ck.model);
        assert_eq!((back.mode, back.opt, back.seed), (ck.mode, ck.opt, ck.seed));
        assert_eq!(back.total_epochs, ck.total_epochs);
        assert_eq!(back.hyper_fp, ck.hyper_fp);
        assert_eq!((back.epoch_next, back.step), (ck.epoch_next, ck.step));
        assert_eq!(back.rng, ck.rng);
        assert_eq!(back.best_val.to_bits(), ck.best_val.to_bits());
        assert_eq!(back.best_epoch, ck.best_epoch);
        assert_eq!(back.test_at_best.to_bits(), ck.test_at_best.to_bits());
        assert_eq!((back.stale, back.diverged_steps), (ck.stale, ck.diverged_steps));
        assert_eq!(back.curves.len(), ck.curves.len());
        for (a, b) in back.curves.iter().zip(&ck.curves) {
            assert_eq!(a.epoch, b.epoch);
            assert_eq!(a.lr.to_bits(), b.lr.to_bits());
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits());
            assert_eq!(a.val_err.to_bits(), b.val_err.to_bits());
        }
        for (a, b) in [
            (&back.state.params, &ck.state.params),
            (&back.state.m, &ck.state.m),
            (&back.state.v, &ck.state.v),
        ] {
            let bits = |t: &[Vec<f32>]| -> Vec<Vec<u32>> {
                t.iter().map(|v| v.iter().map(|x| x.to_bits()).collect()).collect()
            };
            assert_eq!(bits(a), bits(b));
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn every_truncation_and_header_flip_is_rejected() {
        let dir = tmpdir("trunc");
        let ck = toy(1);
        let path = epoch_path(&dir, 1);
        save(&ck, &path, None).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert!(load(&path).is_ok());
        let scratch = dir.join("scratch.bcckpt");
        for cut in 0..bytes.len() {
            std::fs::write(&scratch, &bytes[..cut]).unwrap();
            assert!(load(&scratch).is_err(), "truncation at byte {cut} accepted");
        }
        for at in 0..bytes.len().min(96) {
            let mut mutated = bytes.clone();
            mutated[at] ^= 0xFF;
            std::fs::write(&scratch, &mutated).unwrap();
            assert!(load(&scratch).is_err(), "flip at byte {at} accepted");
        }
        // flipped CRC trailer specifically
        let mut flipped = bytes.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x01;
        std::fs::write(&scratch, &flipped).unwrap();
        let err = load(&scratch).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
        // trailing junk is corruption too
        let mut padded = bytes.clone();
        padded.extend_from_slice(b"junk");
        std::fs::write(&scratch, &padded).unwrap();
        assert!(load(&scratch).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn all_zero_rng_state_is_rejected() {
        let dir = tmpdir("zrng");
        let mut ck = toy(1);
        ck.rng = [0; 4];
        let path = epoch_path(&dir, 1);
        save(&ck, &path, None).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("RNG"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn retention_prunes_oldest() {
        let dir = tmpdir("keep");
        for e in 1..=5 {
            save_into_dir(&dir, &toy(e), 2, None).unwrap();
        }
        let files = list(&dir);
        assert_eq!(files.len(), 2, "{files:?}");
        assert!(files[0].ends_with("ckpt-000004.bcckpt"), "{files:?}");
        assert!(files[1].ends_with("ckpt-000005.bcckpt"), "{files:?}");
        // keep == 0 disables pruning
        for e in 6..=8 {
            save_into_dir(&dir, &toy(e), 0, None).unwrap();
        }
        assert_eq!(list(&dir).len(), 5);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn latest_good_skips_corrupt_newer_files() {
        let dir = tmpdir("fallback");
        for e in 1..=3 {
            save_into_dir(&dir, &toy(e), 0, None).unwrap();
        }
        // tear the newest
        let newest = epoch_path(&dir, 3);
        let mut bytes = std::fs::read(&newest).unwrap();
        bytes.truncate(bytes.len() / 2);
        std::fs::write(&newest, &bytes).unwrap();
        let (path, ck) = latest_good(&dir).expect("epoch-2 checkpoint should load");
        assert!(path.ends_with("ckpt-000002.bcckpt"), "{}", path.display());
        assert_eq!(ck.epoch_next, 2);
        // corrupt everything -> None
        for p in list(&dir) {
            std::fs::write(&p, b"garbage").unwrap();
        }
        assert!(latest_good(&dir).is_none());
        // missing dir -> None, not an error
        assert!(latest_good(&dir.join("nope")).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_checkpoint_injection_produces_a_detectably_torn_file() {
        let dir = tmpdir("torn");
        let plan = FaultPlan::parse("torn_checkpoint@1", 0).unwrap();
        let path = epoch_path(&dir, 1);
        save(&toy(1), &path, Some(&plan)).unwrap();
        assert_eq!(plan.injected_torn_checkpoints(), 1);
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("checksum") || err.contains("truncated"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_leaves_no_temp_litter() {
        let dir = tmpdir("litter");
        let path = epoch_path(&dir, 1);
        save(&toy(1), &path, None).unwrap();
        save(&toy(1), &path, None).unwrap(); // overwrite in place
        assert!(load(&path).is_ok());
        let leftovers: Vec<String> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            .filter(|n| n.contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "temp litter: {leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
