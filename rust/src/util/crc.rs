//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320) — the integrity
//! trailer for `.bcpack` artifacts.
//!
//! Bitwise and table-free on purpose: artifact (de)serialization is
//! I/O-bound, files are small (packed weights), and this keeps the
//! vendored surface tiny and obviously correct.

pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = !0u32;
    for &b in data {
        crc ^= u32::from(b);
        for _ in 0..8 {
            // branch-free: mask is all-ones iff the low bit is set
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_the_ieee_check_vector() {
        // the canonical CRC-32 check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    #[test]
    fn empty_input_is_zero() {
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn single_bit_flips_change_the_checksum() {
        let base = b"BCPACK02 payload bytes".to_vec();
        let reference = crc32(&base);
        for i in 0..base.len() {
            for bit in 0..8 {
                let mut corrupt = base.clone();
                corrupt[i] ^= 1 << bit;
                assert_ne!(crc32(&corrupt), reference, "flip at byte {i} bit {bit}");
            }
        }
    }
}
