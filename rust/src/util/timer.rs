//! Timing helpers shared by the coordinator's metrics and the bench harness.

use std::time::Instant;

/// Wall-clock stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn reset(&mut self) {
        self.start = Instant::now();
    }
}

/// Online mean/min/max/percentile accumulator for step latencies.
#[derive(Default, Clone)]
pub struct LatencyStats {
    samples: Vec<f64>,
}

impl LatencyStats {
    pub fn record(&mut self, seconds: f64) {
        self.samples.push(seconds);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        // total_cmp: a NaN sample (e.g. from a poisoned clock delta)
        // sorts deterministically instead of panicking the whole report.
        s.sort_by(f64::total_cmp);
        let idx = ((p / 100.0) * (s.len() - 1) as f64).round() as usize;
        s[idx.min(s.len() - 1)]
    }

    /// Fold another accumulator's samples into this one (the load
    /// generator merges per-thread recorders into one report).
    pub fn merge(&mut self, other: &LatencyStats) {
        self.samples.extend_from_slice(&other.samples);
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_stats_basics() {
        let mut s = LatencyStats::default();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.record(v);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(50.0), 3.0);
        assert_eq!(s.percentile(100.0), 5.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // regression: sort_by(partial_cmp().unwrap()) panicked on NaN
        let mut s = LatencyStats::default();
        for v in [2.0, f64::NAN, 1.0, 3.0] {
            s.record(v);
        }
        // NaN sorts deterministically (total order); the finite
        // percentiles stay meaningful
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(33.0), 2.0);
    }

    #[test]
    fn merge_combines_sample_sets() {
        let mut a = LatencyStats::default();
        let mut b = LatencyStats::default();
        a.record(1.0);
        b.record(3.0);
        b.record(2.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.percentile(100.0), 3.0);
        assert_eq!(a.min(), 1.0);
    }

    #[test]
    fn merge_empty_edges() {
        // the loadgen merges per-thread recorders; threads that never
        // completed a request contribute empty rings
        let mut a = LatencyStats::default();
        let b = LatencyStats::default();
        a.merge(&b); // empty into empty
        assert_eq!(a.count(), 0);
        assert_eq!(a.mean(), 0.0);
        assert_eq!(a.percentile(50.0), 0.0);
        a.record(1.5);
        a.merge(&b); // empty into non-empty: unchanged
        assert_eq!(a.count(), 1);
        assert_eq!(a.max(), 1.5);
        let mut c = LatencyStats::default();
        c.merge(&a); // non-empty into empty
        assert_eq!(c.count(), 1);
        assert_eq!(c.min(), 1.5);
    }

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }
}
