//! Vendored `anyhow`-equivalent error substrate.
//!
//! The offline crate registry carries no `anyhow`, so this module provides
//! the small subset the crate actually uses: a string-backed [`Error`], a
//! defaulted [`Result`] alias, the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros (exported at the crate root).
//! Context frames are flattened eagerly into one message, so `{e}` and
//! `{e:#}` both print the full chain.

use std::fmt;

/// A flattened error message (context chain joined with `": "`).
#[derive(Debug)]
pub struct Error(String);

impl Error {
    /// Build an error from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error(m.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error(e.to_string())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error(s.to_string())
    }
}

/// Crate-wide result type, defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `.context(...)` / `.with_context(|| ...)` on results and options.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or a displayable expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn bail_formats() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "boom 42");
    }

    #[test]
    fn context_chains() {
        let r: std::io::Result<()> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.context("reading manifest").unwrap_err();
        let msg = e.to_string();
        assert!(msg.starts_with("reading manifest: "), "{msg}");
        assert!(msg.contains("gone"), "{msg}");
    }

    #[test]
    fn with_context_is_lazy_on_ok() {
        let r: Result<i32, String> = Ok(3);
        let v = r
            .with_context(|| -> String { panic!("must not run") })
            .unwrap();
        assert_eq!(v, 3);
    }

    #[test]
    fn option_context() {
        let v: Option<i32> = None;
        let e = v.context("missing field").unwrap_err();
        assert_eq!(e.to_string(), "missing field");
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert_eq!(check(2).unwrap(), 2);
        assert!(check(-1).unwrap_err().to_string().contains("-1"));
    }

    #[test]
    fn anyhow_accepts_expressions() {
        let s = String::from("already a message");
        let e = anyhow!(s);
        assert_eq!(e.to_string(), "already a message");
    }
}
