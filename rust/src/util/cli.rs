//! Tiny CLI argument substrate (no clap in the offline registry).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments, with typed getters and a generated usage line.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    seen: Vec<String>,
}

impl Args {
    pub fn parse_from<I: IntoIterator<Item = String>>(items: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest positional
                    out.positional.extend(it.by_ref());
                    break;
                }
                let (key, val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let val = match val {
                    Some(v) => v,
                    None => {
                        // consume next token as the value unless it looks
                        // like another flag — then treat as boolean.
                        match it.peek() {
                            Some(nxt) if !nxt.starts_with("--") => it.next().unwrap(),
                            _ => "true".to_string(),
                        }
                    }
                };
                out.seen.push(key.clone());
                out.flags.insert(key, val);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn parse() -> Result<Args, String> {
        Args::parse_from(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f32(&self, key: &str, default: f32) -> f32 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn bool(&self, key: &str, default: bool) -> bool {
        match self.flags.get(key).map(|s| s.as_str()) {
            Some("true") | Some("1") | Some("yes") => true,
            Some("false") | Some("0") | Some("no") => false,
            Some(_) => default,
            None => default,
        }
    }

    /// Error out on unknown flags so typos do not silently use defaults.
    pub fn check_known(&self, known: &[&str]) -> Result<(), String> {
        for k in &self.seen {
            if !known.contains(&k.as_str()) {
                return Err(format!(
                    "unknown flag --{k}; known flags: {}",
                    known.join(", ")
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &[&str]) -> Args {
        Args::parse_from(s.iter().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_key_value_forms() {
        let a = args(&["train", "--lr", "0.01", "--epochs=50", "--verbose"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.f32("lr", 0.0), 0.01);
        assert_eq!(a.usize("epochs", 0), 50);
        assert!(a.bool("verbose", false));
    }

    #[test]
    fn defaults_apply() {
        let a = args(&[]);
        assert_eq!(a.usize("missing", 7), 7);
        assert_eq!(a.str("name", "x"), "x");
    }

    #[test]
    fn double_dash_terminator() {
        let a = args(&["--x", "1", "--", "--not-a-flag"]);
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn negative_number_values() {
        let a = args(&["--shift=-3.5"]);
        assert_eq!(a.f32("shift", 0.0), -3.5);
    }

    #[test]
    fn unknown_flag_detection() {
        let a = args(&["--good", "1", "--bad", "2"]);
        assert!(a.check_known(&["good"]).is_err());
        assert!(a.check_known(&["good", "bad"]).is_ok());
    }
}
