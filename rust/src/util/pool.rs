//! Vendored fork-join thread pool (std threads only; rayon is not in the
//! offline registry).
//!
//! Design goals, in order:
//!
//! 1. **Zero steady-state allocation.** Dispatching a job allocates
//!    nothing: the job is a borrowed closure published through a
//!    `Mutex`-guarded slot, and workers pull block indices from one
//!    `AtomicUsize` cursor. This is what lets a warmed-up
//!    `ReferenceExecutor::train_step` run allocation-free (see the
//!    counting-allocator test in `runtime/reference.rs`).
//! 2. **Determinism.** There is no work stealing and no per-thread
//!    accumulation: callers split work into blocks whose *results* are
//!    independent of which thread runs them (e.g. disjoint row ranges of a
//!    GEMM output). Kernel results are therefore bit-for-bit identical for
//!    any `BCRUN_THREADS` value.
//! 3. **Simplicity.** One job runs at a time (`submit` mutex); the caller
//!    participates in its own job, so a 1-thread pool degenerates to a
//!    plain loop with no synchronization.
//!
//! The global pool is sized by the `BCRUN_THREADS` env var when set
//! (validated — a typo fails loudly, see [`n_threads_from_env`]), else by
//! `std::thread::available_parallelism()`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Type-erased borrowed job. SAFETY: the submitting thread keeps the
/// closure alive (and blocks) until every worker has finished running it.
type RawJob = *const (dyn Fn() + Sync);

#[derive(Clone, Copy)]
struct SendJob(RawJob);
// SAFETY: the pointee is `Sync` (it is a `&(dyn Fn() + Sync)`) and outlives
// its publication window, enforced by `Pool::run` blocking until done.
unsafe impl Send for SendJob {}

struct State {
    /// Bumped once per dispatched job so workers run each job exactly once.
    epoch: u64,
    job: Option<SendJob>,
    /// Workers still running the current job.
    active: usize,
    /// Set when a worker caught a panic in the current job; re-raised on
    /// the submitting thread so a failing block aborts the step instead of
    /// hanging it.
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
}

/// Fixed-size fork-join pool; see the module docs for the contract.
pub struct Pool {
    shared: Arc<Shared>,
    /// Serializes job submission (one job in flight at a time).
    submit: Mutex<()>,
    /// Total worker count including the participating caller.
    pub n_threads: usize,
    handles: Vec<JoinHandle<()>>,
}

fn worker_loop(shared: &Shared) {
    let mut last_epoch = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != last_epoch {
                    if let Some(j) = st.job {
                        last_epoch = st.epoch;
                        break j;
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // SAFETY: see `RawJob` — the submitter blocks until `active == 0`.
        let f: &(dyn Fn() + Sync) = unsafe { &*job.0 };
        // Catch panics so a failing block can never leave `active`
        // undecremented (which would deadlock the submitter); the flag
        // re-raises the panic on the submitting thread.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
        let mut st = shared.state.lock().unwrap();
        if result.is_err() {
            st.panicked = true;
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

impl Pool {
    /// Spawn a pool with `n_threads` total lanes (the caller is one lane,
    /// so `n_threads - 1` OS threads are created; 1 means fully inline).
    pub fn new(n_threads: usize) -> Pool {
        let n_threads = n_threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let mut handles = Vec::with_capacity(n_threads - 1);
        for _ in 1..n_threads {
            let sh = Arc::clone(&shared);
            handles.push(std::thread::spawn(move || worker_loop(&sh)));
        }
        Pool { shared, submit: Mutex::new(()), n_threads, handles }
    }

    /// Execute `block_fn(0..n_blocks)` across the pool, caller included,
    /// returning when every block has run. Blocks are claimed from an
    /// atomic cursor in index order; no allocation happens on this path.
    pub fn run(&self, n_blocks: usize, block_fn: &(dyn Fn(usize) + Sync)) {
        if n_blocks == 0 {
            return;
        }
        if self.handles.is_empty() || n_blocks == 1 {
            for i in 0..n_blocks {
                block_fn(i);
            }
            return;
        }
        let cursor = AtomicUsize::new(0);
        let drain = || loop {
            let b = cursor.fetch_add(1, Ordering::Relaxed);
            if b >= n_blocks {
                break;
            }
            block_fn(b);
        };
        let _guard = self.submit.lock().unwrap();
        let erased: &(dyn Fn() + Sync) = &drain;
        // SAFETY: lifetime erasure only — we block below until every
        // worker has finished running the closure.
        let raw: SendJob = SendJob(unsafe {
            std::mem::transmute::<&(dyn Fn() + Sync), RawJob>(erased)
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            st.epoch = st.epoch.wrapping_add(1);
            st.job = Some(raw);
            st.active = self.handles.len();
            st.panicked = false;
        }
        self.shared.work_cv.notify_all();
        // Even if the caller's own blocks panic, the job closure must stay
        // alive until every worker is done with it: this guard waits on
        // drop, which runs during unwinding too.
        struct DoneWait<'a>(&'a Shared);
        impl Drop for DoneWait<'_> {
            fn drop(&mut self) {
                let mut st = self.0.state.lock().unwrap();
                while st.active > 0 {
                    st = self.0.done_cv.wait(st).unwrap();
                }
                st.job = None;
            }
        }
        let wait = DoneWait(&self.shared);
        drain();
        drop(wait);
        let st = self.shared.state.lock().unwrap();
        if st.panicked {
            drop(st);
            panic!("pool: a parallel block panicked on a worker thread");
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Pure parse of a `BCRUN_THREADS` value. `None` (unset) -> available
/// parallelism; a set value must be a positive integer or the error names
/// the offending value.
pub fn parse_threads(var: Option<&str>) -> Result<usize, String> {
    match var {
        Some(v) => v
            .trim()
            .parse::<usize>()
            .ok()
            .filter(|&t| t >= 1)
            .ok_or_else(|| {
                format!("BCRUN_THREADS must be a positive integer, got '{v}'")
            }),
        None => Ok(std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)),
    }
}

/// Read one `BCRUN_*` setting from the environment: `Ok(None)` when
/// unset, a named error (instead of a silent default) when the value is
/// not unicode. Shared by the `BCRUN_THREADS` parse here and the
/// `BCRUN_SIMD` parse in `kernel::simd` so both fail the same way.
pub fn env_setting(name: &str) -> Result<Option<String>, String> {
    match std::env::var(name) {
        Ok(v) => Ok(Some(v)),
        Err(std::env::VarError::NotPresent) => Ok(None),
        Err(e) => Err(format!("{name} is not valid unicode: {e}")),
    }
}

/// Parse the `BCRUN_THREADS` override from the environment. Checked early
/// by `bcrun` so typos fail loudly instead of silently using a default.
pub fn n_threads_from_env() -> Result<usize, String> {
    parse_threads(env_setting("BCRUN_THREADS")?.as_deref())
}

static GLOBAL: OnceLock<Pool> = OnceLock::new();

/// The process-wide pool every kernel dispatches to. First use spawns the
/// workers; an invalid `BCRUN_THREADS` panics with the parse error
/// (`bcrun` validates the variable up front to turn that into a clean
/// CLI error instead).
pub fn global() -> &'static Pool {
    GLOBAL.get_or_init(|| {
        let n = n_threads_from_env().unwrap_or_else(|e| panic!("{e}"));
        Pool::new(n)
    })
}

/// Split `n_rows` into `grain`-sized contiguous ranges and run
/// `f(lo, hi)` for each across the global pool. The primitive every
/// kernel parallelizes with; per-range results must not depend on the
/// split (disjoint output ranges), which keeps results thread-count
/// independent.
pub fn par_rows(n_rows: usize, grain: usize, f: &(dyn Fn(usize, usize) + Sync)) {
    if n_rows == 0 {
        return;
    }
    let grain = grain.max(1);
    let blocks = n_rows.div_ceil(grain);
    let pool = global();
    if blocks <= 1 || pool.n_threads == 1 {
        f(0, n_rows);
        return;
    }
    pool.run(blocks, &|bi| {
        let lo = bi * grain;
        let hi = (lo + grain).min(n_rows);
        f(lo, hi);
    });
}

/// Shared mutable base pointer for writing *disjoint* ranges of one buffer
/// from pool blocks (the safe-slice route would need per-block ownership).
pub struct SendPtr<T>(pub *mut T);

// SAFETY: callers only touch disjoint ranges and the buffer outlives the
// dispatch (the pool blocks until all ranges are written).
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Reborrow `len` elements starting at `start` as a mutable slice.
    ///
    /// # Safety
    ///
    /// `start..start + len` must be in bounds of the original buffer, must
    /// not overlap any range another thread touches concurrently, and the
    /// buffer must outlive the use (guaranteed when called from a
    /// [`Pool::run`] block over disjoint ranges).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0.add(start), len)
    }

    /// Write one element at `idx`.
    ///
    /// # Safety
    ///
    /// Same contract as [`SendPtr::slice`] for the single index `idx`.
    pub unsafe fn write(&self, idx: usize, value: T) {
        std::ptr::write(self.0.add(idx), value);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_executes_every_block_exactly_once() {
        let pool = Pool::new(4);
        let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
        pool.run(97, &|b| {
            hits[b].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
        // a second job on the same pool also runs to completion
        let total = AtomicU64::new(0);
        pool.run(10, &|b| {
            total.fetch_add(b as u64, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = Pool::new(1);
        let seen = std::sync::Mutex::new(Vec::new());
        pool.run(5, &|b| {
            seen.lock().unwrap().push(b);
        });
        assert_eq!(*seen.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn par_rows_covers_range_with_disjoint_writes() {
        let n = 1003;
        let mut out = vec![0u32; n];
        let ptr = SendPtr(out.as_mut_ptr());
        par_rows(n, 64, &|lo, hi| {
            // SAFETY: ranges from par_rows are disjoint and in bounds.
            let s = unsafe { ptr.slice(lo, hi - lo) };
            for (off, v) in s.iter_mut().enumerate() {
                *v = (lo + off) as u32;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
    }

    #[test]
    fn concurrent_submitters_serialize() {
        // two threads race to submit jobs; the submit mutex must keep each
        // job's blocks consistent.
        let pool = std::sync::Arc::new(Pool::new(3));
        let mut joins = vec![];
        for t in 0..2u64 {
            let p = std::sync::Arc::clone(&pool);
            joins.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let sum = AtomicU64::new(0);
                    p.run(20, &|b| {
                        sum.fetch_add(b as u64 + t, Ordering::Relaxed);
                    });
                    assert_eq!(sum.load(Ordering::Relaxed), 190 + 20 * t);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }

    #[test]
    fn panicking_block_aborts_the_job_instead_of_deadlocking() {
        let pool = Pool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(64, &|b| {
                assert!(b % 7 != 3, "boom at {b}");
            });
        }));
        assert!(result.is_err(), "panic must propagate to the submitter");
        // the pool stays usable for the next job
        let total = AtomicU64::new(0);
        pool.run(8, &|b| {
            total.fetch_add(b as u64, Ordering::Relaxed);
        });
        assert_eq!(total.load(Ordering::Relaxed), 28);
    }

    #[test]
    fn thread_count_parsing() {
        // pure parse only — setting the real env var would race the other
        // tests' first-touch of the global pool.
        assert_eq!(parse_threads(Some("3")), Ok(3));
        assert_eq!(parse_threads(Some(" 8 ")), Ok(8));
        assert!(parse_threads(None).unwrap() >= 1);
        for bad in ["0", "-2", "abc", "1.5", ""] {
            let err = parse_threads(Some(bad)).unwrap_err();
            assert!(
                err.contains("positive integer") && err.contains(bad.trim()),
                "unhelpful error for {bad:?}: {err}"
            );
        }
    }
}
