//! Deterministic PRNG substrate (SplitMix64 + xoshiro256++).
//!
//! The offline registry carries no `rand` crate, so the coordinator owns its
//! randomness: dataset synthesis, shuffling, weight-noise seeds. Everything
//! is seedable and reproducible — multi-seed trials (Table 2's mean ± std)
//! derive per-trial streams from a root seed.

/// SplitMix64: used to seed xoshiro and as a cheap standalone stream.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 2^256 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Derive an independent stream (for per-trial / per-worker RNGs).
    ///
    /// The tag is mixed through SplitMix64 before xoring: a plain
    /// `tag.wrapping_mul(...)` is 0 for tag 0, which would make
    /// `fork(0)` collide with `Rng::new(next_u64())`.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ SplitMix64::new(tag).next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here:
        // bias is < 2^-40 for any n that fits a dataset index.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<u32> {
        let mut p: Vec<u32> = (0..n as u32).collect();
        self.shuffle(&mut p);
        p
    }

    /// Raw xoshiro state, for checkpointing a stream mid-flight. Restore
    /// with [`Rng::from_state`] and the stream continues bit-exactly.
    #[inline]
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a captured [`Rng::state`]. The all-zero
    /// state is degenerate (xoshiro would emit only zeros) and can only
    /// come from a corrupt capture, so it is rejected loudly.
    pub fn from_state(s: [u64; 4]) -> Rng {
        assert!(s.iter().any(|&w| w != 0), "all-zero xoshiro256++ state");
        Rng { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval_and_centered() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = r.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Rng::new(5);
        let p = r.permutation(1000);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000u32).collect::<Vec<_>>());
    }

    #[test]
    fn fork_decorrelates() {
        let mut root = Rng::new(11);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn state_capture_resumes_stream_bit_exactly() {
        let mut a = Rng::new(123);
        for _ in 0..37 {
            a.next_u64(); // advance mid-stream
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..64).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let replay: Vec<u64> = (0..64).map(|_| b.next_u64()).collect();
        assert_eq!(tail, replay);
    }

    #[test]
    #[should_panic(expected = "all-zero")]
    fn all_zero_state_is_rejected() {
        let _ = Rng::from_state([0; 4]);
    }

    #[test]
    fn fork_zero_tag_differs_from_untagged_stream() {
        // regression: tag 0 used to contribute nothing to the fork seed,
        // so fork(0) collided with Rng::new(next_u64()).
        let mut root_a = Rng::new(17);
        let mut root_b = Rng::new(17);
        let mut forked = root_a.fork(0);
        let mut plain = Rng::new(root_b.next_u64());
        let same = (0..64).filter(|_| forked.next_u64() == plain.next_u64()).count();
        assert_eq!(same, 0, "fork(0) must not collide with the untagged stream");
    }
}
