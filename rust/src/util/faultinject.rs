//! Deterministic fault injection for the serving robustness layer.
//!
//! A `FaultPlan` is parsed from the `BCRUN_FAULTS` environment variable
//! (or built programmatically in tests) and threaded through the serve
//! worker and batcher threads. Each injection site draws a seeded,
//! *replayable* decision per trial, so a chaos run can assert exact
//! accounting: the number of panics the plan reports having fired must
//! equal the restart counters the supervisor publishes in `/stats`.
//!
//! Spec grammar (comma-separated, whitespace-tolerant):
//!
//! ```text
//! panic_worker@0.01,panic_batcher@0.005,slow_batch=5ms@0.05,seed=7
//! ```
//!
//! - `panic_worker@P`  — each `/predict` dispatch panics with probability P
//! - `panic_batcher@P` — each non-empty batch panics (before the forward)
//!                       with probability P
//! - `slow_batch=DUR@P` — each non-empty batch sleeps DUR (`us`/`ms`/`s`
//!                       suffix) with probability P
//! - `seed=N`          — seed for the decision stream (default 0)
//!
//! Training sites (the checkpoint/resume chaos harness, `chaos_train`):
//!
//! - `panic_step@P`      — each trainer step panics *before* the forward
//!                         with probability P (a hard mid-epoch crash)
//! - `torn_checkpoint@P` — each checkpoint save truncates the serialized
//!                         bytes with probability P, simulating a torn
//!                         write that the CRC trailer must catch at load
//! - `nan_grad@P`        — each train step poisons one weight gradient
//!                         with NaN with probability P, exercising the
//!                         divergence sentinels
//!
//! When `BCRUN_FAULTS` is unset the plan is absent (`None`) and the hot
//! paths pay only an `Option` check — production runs carry no injection
//! overhead and no behavioral change.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::SplitMix64;

/// One injection site: a probability plus trial/fired accounting.
#[derive(Debug)]
struct FaultSite {
    prob: f64,
    trials: AtomicU64,
    fired: AtomicU64,
}

impl FaultSite {
    fn new(prob: f64) -> Self {
        Self { prob, trials: AtomicU64::new(0), fired: AtomicU64::new(0) }
    }

    /// Draw this site's next decision. Deterministic in (seed, tag,
    /// trial index): two plans with the same spec and seed fire on the
    /// exact same trial numbers, regardless of thread interleaving of
    /// *other* sites (each site counts its own trials).
    fn roll(&self, seed: u64, tag: u64) -> bool {
        let i = self.trials.fetch_add(1, Ordering::Relaxed);
        let mut h = SplitMix64::new(
            seed ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ i.wrapping_mul(0xA24B_AED4_963E_E407),
        );
        // top 53 bits -> uniform in [0, 1)
        let u = (h.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let hit = u < self.prob;
        if hit {
            self.fired.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }
}

/// A parsed, seeded fault-injection plan. Shared (`Arc`) between the
/// server threads and the chaos test that audits the counters.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    panic_worker: Option<FaultSite>,
    panic_batcher: Option<FaultSite>,
    slow_batch: Option<(Duration, FaultSite)>,
    panic_step: Option<FaultSite>,
    torn_checkpoint: Option<FaultSite>,
    nan_grad: Option<FaultSite>,
}

const WORKER_TAG: u64 = 0x5745_524b; // "WERK"
const BATCHER_TAG: u64 = 0x4241_5443; // "BATC"
const SLOW_TAG: u64 = 0x534c_4f57; // "SLOW"
const STEP_TAG: u64 = 0x5354_4550; // "STEP"
const TORN_TAG: u64 = 0x544f_524e; // "TORN"
const NANG_TAG: u64 = 0x4e41_4e47; // "NANG"

impl FaultPlan {
    /// Parse a spec string. `default_seed` applies unless the spec
    /// carries its own `seed=N` entry.
    pub fn parse(spec: &str, default_seed: u64) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan {
            seed: default_seed,
            panic_worker: None,
            panic_batcher: None,
            slow_batch: None,
            panic_step: None,
            torn_checkpoint: None,
            nan_grad: None,
        };
        for raw in spec.split(',') {
            let part = raw.trim();
            if part.is_empty() {
                continue;
            }
            if let Some(v) = part.strip_prefix("seed=") {
                plan.seed = v
                    .parse()
                    .map_err(|_| format!("BCRUN_FAULTS: bad seed {v:?}"))?;
            } else if let Some(p) = part.strip_prefix("panic_worker@") {
                plan.panic_worker = Some(FaultSite::new(parse_prob(p)?));
            } else if let Some(p) = part.strip_prefix("panic_batcher@") {
                plan.panic_batcher = Some(FaultSite::new(parse_prob(p)?));
            } else if let Some(rest) = part.strip_prefix("slow_batch=") {
                let (dur, prob) = rest.split_once('@').ok_or_else(|| {
                    format!("BCRUN_FAULTS: slow_batch needs DUR@P, got {rest:?}")
                })?;
                plan.slow_batch =
                    Some((parse_duration(dur)?, FaultSite::new(parse_prob(prob)?)));
            } else if let Some(p) = part.strip_prefix("panic_step@") {
                plan.panic_step = Some(FaultSite::new(parse_prob(p)?));
            } else if let Some(p) = part.strip_prefix("torn_checkpoint@") {
                plan.torn_checkpoint = Some(FaultSite::new(parse_prob(p)?));
            } else if let Some(p) = part.strip_prefix("nan_grad@") {
                plan.nan_grad = Some(FaultSite::new(parse_prob(p)?));
            } else {
                return Err(format!(
                    "BCRUN_FAULTS: unknown fault {part:?} (grammar: \
                     panic_worker@P, panic_batcher@P, slow_batch=DUR@P, \
                     panic_step@P, torn_checkpoint@P, nan_grad@P, seed=N)"
                ));
            }
        }
        Ok(plan)
    }

    /// Read `BCRUN_FAULTS`; unset or empty means no injection.
    pub fn from_env() -> Result<Option<FaultPlan>, String> {
        match std::env::var("BCRUN_FAULTS") {
            Err(_) => Ok(None),
            Ok(s) if s.trim().is_empty() => Ok(None),
            Ok(s) => FaultPlan::parse(&s, 0).map(Some),
        }
    }

    /// Worker injection point (the `/predict` dispatch). Panics when the
    /// seeded decision fires; the supervisor catches it, answers the
    /// connection with 500, and bumps `worker_restarts`.
    pub fn maybe_panic_worker(&self) {
        if self.roll_worker() {
            panic!("fault injection: panic_worker");
        }
    }

    /// Batcher injection point (after a non-empty batch is taken, before
    /// the forward). The supervisor fails the held rows and respawns the
    /// loop with a fresh workspace.
    pub fn maybe_panic_batcher(&self) {
        if self.roll_batcher() {
            panic!("fault injection: panic_batcher");
        }
    }

    /// Batch-delay injection point: how long this batch should stall, if
    /// at all. The caller sleeps; this only decides.
    pub fn slow_batch(&self) -> Option<Duration> {
        let (dur, site) = self.slow_batch.as_ref()?;
        site.roll(self.seed, SLOW_TAG).then_some(*dur)
    }

    /// Trainer injection point (start of every training step, before the
    /// forward). A fired decision is a hard crash: the process (or the
    /// chaos test's `catch_unwind`) dies mid-epoch, which a later
    /// `--resume` must recover from bit-exactly.
    pub fn maybe_panic_step(&self) {
        if self.roll_step() {
            panic!("fault injection: panic_step");
        }
    }

    // Decision-only entry points (no panic) so tests can replay the
    // stream without unwinding.
    #[doc(hidden)]
    pub fn roll_worker(&self) -> bool {
        self.panic_worker
            .as_ref()
            .is_some_and(|s| s.roll(self.seed, WORKER_TAG))
    }

    #[doc(hidden)]
    pub fn roll_batcher(&self) -> bool {
        self.panic_batcher
            .as_ref()
            .is_some_and(|s| s.roll(self.seed, BATCHER_TAG))
    }

    #[doc(hidden)]
    pub fn roll_step(&self) -> bool {
        self.panic_step
            .as_ref()
            .is_some_and(|s| s.roll(self.seed, STEP_TAG))
    }

    /// Checkpoint-save injection point: should this save tear (truncate)
    /// the on-disk bytes? The writer mangles; this only decides.
    pub fn roll_torn_checkpoint(&self) -> bool {
        self.torn_checkpoint
            .as_ref()
            .is_some_and(|s| s.roll(self.seed, TORN_TAG))
    }

    /// Gradient-poison injection point: should this step's first weight
    /// gradient become NaN? The executor mangles; this only decides.
    pub fn roll_nan_grad(&self) -> bool {
        self.nan_grad
            .as_ref()
            .is_some_and(|s| s.roll(self.seed, NANG_TAG))
    }

    /// How many worker panics this plan has actually fired.
    pub fn injected_worker_panics(&self) -> u64 {
        self.panic_worker.as_ref().map_or(0, |s| s.fired.load(Ordering::Relaxed))
    }

    /// How many batcher panics this plan has actually fired.
    pub fn injected_batcher_panics(&self) -> u64 {
        self.panic_batcher.as_ref().map_or(0, |s| s.fired.load(Ordering::Relaxed))
    }

    /// How many batches this plan has actually stalled.
    pub fn injected_slow_batches(&self) -> u64 {
        self.slow_batch
            .as_ref()
            .map_or(0, |(_, s)| s.fired.load(Ordering::Relaxed))
    }

    /// How many trainer-step panics this plan has actually fired.
    pub fn injected_step_panics(&self) -> u64 {
        self.panic_step.as_ref().map_or(0, |s| s.fired.load(Ordering::Relaxed))
    }

    /// How many checkpoint saves this plan has actually torn.
    pub fn injected_torn_checkpoints(&self) -> u64 {
        self.torn_checkpoint
            .as_ref()
            .map_or(0, |s| s.fired.load(Ordering::Relaxed))
    }

    /// How many gradients this plan has actually poisoned.
    pub fn injected_nan_grads(&self) -> u64 {
        self.nan_grad.as_ref().map_or(0, |s| s.fired.load(Ordering::Relaxed))
    }

    /// Human-readable recap for the serve startup banner.
    pub fn summary(&self) -> String {
        let mut parts = Vec::new();
        if let Some(s) = &self.panic_worker {
            parts.push(format!("panic_worker@{}", s.prob));
        }
        if let Some(s) = &self.panic_batcher {
            parts.push(format!("panic_batcher@{}", s.prob));
        }
        if let Some((d, s)) = &self.slow_batch {
            parts.push(format!("slow_batch={}us@{}", d.as_micros(), s.prob));
        }
        if let Some(s) = &self.panic_step {
            parts.push(format!("panic_step@{}", s.prob));
        }
        if let Some(s) = &self.torn_checkpoint {
            parts.push(format!("torn_checkpoint@{}", s.prob));
        }
        if let Some(s) = &self.nan_grad {
            parts.push(format!("nan_grad@{}", s.prob));
        }
        if parts.is_empty() {
            parts.push("no active sites".to_string());
        }
        format!("{} (seed {})", parts.join(", "), self.seed)
    }
}

fn parse_prob(s: &str) -> Result<f64, String> {
    let p: f64 = s
        .trim()
        .parse()
        .map_err(|_| format!("BCRUN_FAULTS: bad probability {s:?}"))?;
    if !p.is_finite() || !(0.0..=1.0).contains(&p) {
        return Err(format!("BCRUN_FAULTS: probability {s:?} not in [0, 1]"));
    }
    Ok(p)
}

fn parse_duration(s: &str) -> Result<Duration, String> {
    let s = s.trim();
    // "ms" before "s": a millisecond literal also ends in 's'
    let (num, unit_scale_us) = if let Some(n) = s.strip_suffix("ms") {
        (n, 1_000u64)
    } else if let Some(n) = s.strip_suffix("us") {
        (n, 1u64)
    } else if let Some(n) = s.strip_suffix('s') {
        (n, 1_000_000u64)
    } else {
        return Err(format!("BCRUN_FAULTS: duration {s:?} needs a us/ms/s suffix"));
    };
    let v: u64 = num
        .parse()
        .map_err(|_| format!("BCRUN_FAULTS: bad duration {s:?}"))?;
    Ok(Duration::from_micros(v.saturating_mul(unit_scale_us)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar() {
        let p =
            FaultPlan::parse("panic_worker@0.01, panic_batcher@0.005,slow_batch=5ms@0.05,seed=7", 0)
                .unwrap();
        assert_eq!(p.seed, 7);
        assert!(p.panic_worker.is_some());
        assert!(p.panic_batcher.is_some());
        assert_eq!(p.slow_batch.as_ref().unwrap().0, Duration::from_millis(5));
        let s = p.summary();
        assert!(s.contains("panic_worker@0.01"), "{s}");
        assert!(s.contains("seed 7"), "{s}");
    }

    #[test]
    fn duration_suffixes() {
        let plan = |spec: &str| FaultPlan::parse(spec, 0).unwrap();
        assert_eq!(
            plan("slow_batch=250us@1").slow_batch.unwrap().0,
            Duration::from_micros(250)
        );
        assert_eq!(plan("slow_batch=5ms@1").slow_batch.unwrap().0, Duration::from_millis(5));
        assert_eq!(plan("slow_batch=1s@1").slow_batch.unwrap().0, Duration::from_secs(1));
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "panic_worker@1.5",
            "panic_worker@-0.1",
            "panic_worker@nope",
            "panic_worker@NaN",
            "slow_batch=5@0.1",
            "slow_batch=5ms",
            "explode@0.5",
            "seed=abc",
        ] {
            assert!(FaultPlan::parse(bad, 0).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn empty_spec_is_inert() {
        let p = FaultPlan::parse("", 0).unwrap();
        for _ in 0..100 {
            assert!(!p.roll_worker());
            assert!(!p.roll_batcher());
            assert!(p.slow_batch().is_none());
        }
        assert_eq!(p.injected_worker_panics(), 0);
        assert_eq!(p.injected_batcher_panics(), 0);
        assert_eq!(p.injected_slow_batches(), 0);
    }

    #[test]
    fn decisions_are_deterministic_per_seed() {
        let a = FaultPlan::parse("panic_worker@0.5", 42).unwrap();
        let b = FaultPlan::parse("panic_worker@0.5", 42).unwrap();
        let seq_a: Vec<bool> = (0..256).map(|_| a.roll_worker()).collect();
        let seq_b: Vec<bool> = (0..256).map(|_| b.roll_worker()).collect();
        assert_eq!(seq_a, seq_b);
        assert_eq!(a.injected_worker_panics(), b.injected_worker_panics());

        let c = FaultPlan::parse("panic_worker@0.5", 43).unwrap();
        let seq_c: Vec<bool> = (0..256).map(|_| c.roll_worker()).collect();
        assert_ne!(seq_a, seq_c, "different seeds should diverge");
    }

    #[test]
    fn fired_counter_matches_true_rolls() {
        let p = FaultPlan::parse("panic_batcher@0.3", 9).unwrap();
        let mut fired = 0u64;
        for _ in 0..1000 {
            if p.roll_batcher() {
                fired += 1;
            }
        }
        assert_eq!(p.injected_batcher_panics(), fired);
        // rate sanity: ~300 expected, generous band
        assert!((150..=450).contains(&fired), "fired {fired}");
    }

    #[test]
    fn probability_extremes() {
        let never = FaultPlan::parse("panic_worker@0", 1).unwrap();
        let always = FaultPlan::parse("panic_worker@1", 1).unwrap();
        for _ in 0..100 {
            assert!(!never.roll_worker());
            assert!(always.roll_worker());
        }
        assert_eq!(always.injected_worker_panics(), 100);
    }

    #[test]
    fn slow_batch_decision_counts() {
        let p = FaultPlan::parse("slow_batch=2ms@1", 5).unwrap();
        for _ in 0..7 {
            assert_eq!(p.slow_batch(), Some(Duration::from_millis(2)));
        }
        assert_eq!(p.injected_slow_batches(), 7);
    }

    #[test]
    fn maybe_panic_actually_panics() {
        let p = FaultPlan::parse("panic_worker@1", 0).unwrap();
        let err = std::panic::catch_unwind(|| p.maybe_panic_worker());
        assert!(err.is_err());
        assert_eq!(p.injected_worker_panics(), 1);
    }

    #[test]
    fn parses_training_sites() {
        let p = FaultPlan::parse("panic_step@0.02,torn_checkpoint@0.5,nan_grad@0.1,seed=3", 0)
            .unwrap();
        assert_eq!(p.seed, 3);
        assert!(p.panic_step.is_some());
        assert!(p.torn_checkpoint.is_some());
        assert!(p.nan_grad.is_some());
        let s = p.summary();
        assert!(s.contains("panic_step@0.02"), "{s}");
        assert!(s.contains("torn_checkpoint@0.5"), "{s}");
        assert!(s.contains("nan_grad@0.1"), "{s}");
        for bad in ["panic_step@2", "torn_checkpoint@x", "nan_grad@-1"] {
            assert!(FaultPlan::parse(bad, 0).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn training_sites_count_exactly_and_replay_deterministically() {
        let a = FaultPlan::parse("panic_step@0.25,torn_checkpoint@0.25,nan_grad@0.25", 21).unwrap();
        let b = FaultPlan::parse("panic_step@0.25,torn_checkpoint@0.25,nan_grad@0.25", 21).unwrap();
        let (mut s, mut t, mut n) = (0u64, 0u64, 0u64);
        for _ in 0..400 {
            assert_eq!(a.roll_step(), b.roll_step());
            assert_eq!(a.roll_torn_checkpoint(), b.roll_torn_checkpoint());
            assert_eq!(a.roll_nan_grad(), b.roll_nan_grad());
        }
        for _ in 0..400 {
            s += a.roll_step() as u64;
            t += a.roll_torn_checkpoint() as u64;
            n += a.roll_nan_grad() as u64;
        }
        assert_eq!(a.injected_step_panics() - b.injected_step_panics(), s);
        assert_eq!(a.injected_torn_checkpoints() - b.injected_torn_checkpoints(), t);
        assert_eq!(a.injected_nan_grads() - b.injected_nan_grads(), n);
    }

    #[test]
    fn maybe_panic_step_panics_and_counts() {
        let p = FaultPlan::parse("panic_step@1", 0).unwrap();
        assert!(std::panic::catch_unwind(|| p.maybe_panic_step()).is_err());
        assert_eq!(p.injected_step_panics(), 1);
    }
}
