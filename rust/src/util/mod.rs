//! Utility substrates: errors, PRNG, JSON, CLI parsing, timing, and the
//! fork-join thread pool.
//!
//! The offline crate registry carries no general-purpose dependencies, so
//! these replace `anyhow`, `rand`, `serde`/`serde_json`, `clap`, parts of
//! `criterion`, and `rayon` respectively (DESIGN.md, "vendored-dependency
//! constraint").

pub mod cli;
pub mod error;
pub mod json;
pub mod pool;
pub mod rng;
pub mod timer;

pub use cli::Args;
pub use error::{Context, Error, Result};
pub use json::Json;
pub use pool::{par_rows, Pool, SendPtr};
pub use rng::{Rng, SplitMix64};
pub use timer::{LatencyStats, Timer};
