//! Utility substrates: errors, PRNG, JSON, CLI parsing, timing, and the
//! fork-join thread pool.
//!
//! The offline crate registry carries no general-purpose dependencies, so
//! these replace `anyhow`, `rand`, `serde`/`serde_json`, `clap`, parts of
//! `criterion`, and `rayon` respectively (DESIGN.md, "vendored-dependency
//! constraint").

pub mod checkpoint;
pub mod cli;
pub mod crc;
pub mod error;
pub mod faultinject;
pub mod json;
pub mod pool;
pub mod rng;
pub mod timer;

pub use checkpoint::Checkpoint;
pub use cli::Args;
pub use crc::crc32;
pub use error::{Context, Error, Result};
pub use faultinject::FaultPlan;
pub use json::Json;
pub use pool::{par_rows, Pool, SendPtr};
pub use rng::{Rng, SplitMix64};
pub use timer::{LatencyStats, Timer};

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Poison-tolerant mutex lock. A thread that panicked while holding one
/// of the serving locks (queue, metrics ring) poisons it; supervision
/// recovers the panicking thread, so every other thread must be able to
/// keep going — the protected data is counters/queues whose invariants
/// hold at every await point, not mid-update state.
pub fn lock_ok<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}
