//! Utility substrates: PRNG, JSON, CLI parsing, timing.
//!
//! The offline crate registry only carries the `xla` dependency tree, so
//! these replace `rand`, `serde`/`serde_json`, `clap` and parts of
//! `criterion` respectively (DESIGN.md par.2, "vendored-dependency
//! constraint").

pub mod cli;
pub mod json;
pub mod rng;
pub mod timer;

pub use cli::Args;
pub use json::Json;
pub use rng::{Rng, SplitMix64};
pub use timer::{LatencyStats, Timer};
