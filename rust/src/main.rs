//! `bcrun` — the BinaryConnect coordinator CLI.
//!
//! Subcommands:
//!   info                         list models (builtin + artifact manifest)
//!   train                        train one configuration, dump curves
//!   hw                           print the hardware cost-model table
//!   export  --out <path>         train, then pack det-BC weights to disk
//!   infer   --packed <path>      run the packed engine on a test set
//!   serve   --packed <path>      online HTTP inference, micro-batched
//!   loadgen --url <http://...>   closed-loop load test against `serve`
//!
//! The backend defaults to the pure-Rust reference executor; pass
//! `--backend pjrt` (with the `pjrt` cargo feature built in) to run the
//! AOT HLO artifacts instead.
//!
//! Examples:
//!   bcrun train --model mlp --dataset mnist --mode stoch --epochs 20
//!   bcrun train --model cifar_mlp --dataset cifar10 --opt adam --mode det

use std::path::PathBuf;
use std::process::ExitCode;

use binaryconnect::coordinator::{
    protocol, train, CheckpointOpts, LrSchedule, ResumeFrom, TrainOpts,
};
use binaryconnect::data::{Corpus, SplitData};
use binaryconnect::hw;
use binaryconnect::runtime::{reference, Executor, Manifest, Mode, Opt, ReferenceExecutor};
use binaryconnect::stats::{feature_tiles, write_pgm, Csv, Histogram};
use binaryconnect::util::error::{Context as _, Result};
use binaryconnect::util::Args;
use binaryconnect::{anyhow, bail, ensure};

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

const USAGE: &str = "\
usage: bcrun <info|train|hw|export|infer|serve|loadgen> [flags]
  common:  --backend reference|pjrt (default reference)
           --artifacts DIR (default artifacts, pjrt only) --data-dir DIR
           env BCRUN_THREADS=N caps the kernel thread pool (default: all cores)
           env BCRUN_SIMD=auto|avx2|sse2|neon|scalar pins the kernel ISA
             (default auto: best of AVX2+FMA > SSE2 on x86-64, NEON on
             aarch64, scalar elsewhere; pinning an ISA the host lacks is
             a startup error)
  train:   --model NAME --dataset mnist|cifar10|svhn --mode none|det|stoch
           (builtins include the conv nets cifar_cnn/svhn_cnn — binary
             conv via im2col on the packed sign-GEMM; `bcrun info` lists
             every model)
           --opt sgd|nesterov|adam --epochs N --lr-start F --lr-end F
           --dropout F --no-lr-scale --seed N --n-train N --n-test N
           --patience N --curves FILE.csv --features FILE.pgm
           --histogram FILE.csv --quiet --no-zca --zca-eps F
           --eval-mode none|det|stoch
           --checkpoint-dir DIR (write ckpt-NNNNNN.bcckpt each boundary)
           --checkpoint-every-epochs N (default 1) --keep N (default 3
             newest checkpoints; 0 = keep all)
           --resume [latest|FILE.bcckpt] (continue a checkpointed run
             bit-exactly; 'latest' picks the newest good checkpoint in
             --checkpoint-dir, falling back past torn files)
           --max-diverged-steps N (roll back to the last checkpoint once
             more than N steps go non-finite; 0 = never roll back)
           --no-skip-diverged (apply updates even on non-finite steps)
           env BCRUN_FAULTS=panic_step@P,torn_checkpoint@P,nan_grad@P
             [,seed=N] injects deterministic training faults (chaos
             testing; inert when unset)
           SIGTERM/ctrl-c checkpoints at the next epoch boundary (when
             --checkpoint-dir is set) and exits resumable
  hw:      --model NAME --batch N
  export:  train flags + --out FILE.bcpack   (train, then pack det weights)
  infer:   --packed FILE.bcpack --dataset D [--n-test N] (mult-free engine)
  serve:   --packed FILE.bcpack --addr HOST (default 127.0.0.1)
           --port N (default 7878; 0 = ephemeral) --port-file PATH
           --max-batch N (default 64) --max-wait-us N (default 200)
           --queue-cap N (default 1024) --workers N (default: cores)
           --bnn (XNOR-popcount engine: binarized hidden activations,
             first layer stays f32; different function than packed-f32,
             same solo == coalesced bit-exactness)
           --default-deadline-ms N (default 0 = no deadline; per-request
             X-Deadline-Ms header overrides; expired rows get 504, and
             admission rejects with 503 when the estimated queue wait
             already exceeds the deadline)
           env BCRUN_FAULTS=panic_worker@P,panic_batcher@P,slow_batch=DUR@P
             [,seed=N] injects deterministic faults for chaos testing
             (inert when unset; panicked threads are supervised: answered
             with 500, counted in /stats, respawned)
           --quiet    endpoints: POST /predict {\"x\":[...]} -> pred+logits,
           GET /healthz, GET /stats, POST /shutdown; SIGTERM/ctrl-c and
           /shutdown both drain in-flight batches before exit; a second
           SIGTERM during the drain force-exits with code 143
  loadgen: --url http://HOST:PORT (default http://127.0.0.1:7878)
           --concurrency N (default 16) --requests N (default 1000)
           --retries N (default 3; capped exponential backoff + jitter,
             honors Retry-After on 500/503/504)
           --seed N   closed-loop: exits non-zero on any non-2xx/transport
           failure after retries (the CI smoke gate)";

fn run() -> Result<()> {
    // Fail fast on an unparseable BCRUN_THREADS or BCRUN_SIMD (typo, or
    // an ISA this host cannot run): the pool/dispatcher would otherwise
    // panic deep inside the first GEMM of the first step.
    binaryconnect::util::pool::n_threads_from_env().map_err(|e| anyhow!(e))?;
    binaryconnect::kernel::simd::resolve_env().map_err(|e| anyhow!(e))?;
    let args = Args::parse().map_err(|e| anyhow!(e))?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => cmd_info(&args),
        "train" => cmd_train(&args),
        "hw" => cmd_hw(&args),
        "export" => cmd_export(&args),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "loadgen" => cmd_loadgen(&args),
        _ => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str("artifacts", "artifacts"))
}

/// Build the selected backend's executor for `--model`. A fault plan is
/// threaded into the reference executor so `nan_grad` injection reaches
/// the gradient path (the PJRT backend has no injection points).
fn load_executor(
    args: &Args,
    faults: Option<std::sync::Arc<binaryconnect::util::FaultPlan>>,
) -> Result<Box<dyn Executor>> {
    let model_name = args.str("model", "mlp");
    let backend = args.str("backend", "reference");
    match backend.as_str() {
        "reference" => {
            let mut exec = ReferenceExecutor::builtin(&model_name)?;
            exec.set_faults(faults);
            Ok(Box::new(exec))
        }
        #[cfg(feature = "pjrt")]
        "pjrt" => {
            let m = Manifest::load(&artifacts_dir(args))?;
            let rt = binaryconnect::runtime::Runtime::cpu()?;
            Ok(Box::new(rt.load_model(m.model(&model_name)?)?))
        }
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => bail!(
            "this build has no PJRT backend; rebuild with `--features pjrt` \
             (needs the offline xla crate, see DESIGN.md)"
        ),
        other => bail!("unknown --backend {other} (want reference or pjrt)"),
    }
}

/// Resolve a model spec by name: the artifact manifest wins when present
/// (its specs carry the real trained-scale shapes), otherwise the builtin
/// registry — so spec-only uses like `hw` work for both backends.
fn model_spec(args: &Args, name: &str) -> Result<binaryconnect::runtime::ModelInfo> {
    let dir = artifacts_dir(args);
    if dir.join("manifest.json").exists() {
        let m = Manifest::load(&dir)?;
        if let Ok(info) = m.model(name) {
            return Ok(info.clone());
        }
    }
    reference::builtin_info(name).ok_or_else(|| {
        anyhow!(
            "model '{name}' is neither in the artifact manifest nor builtin (builtin: {})",
            reference::builtin_names().join(", ")
        )
    })
}

fn cmd_info(args: &Args) -> Result<()> {
    println!("builtin models (reference backend; all trainable):");
    for name in reference::builtin_names() {
        let info = reference::builtin_info(name).unwrap();
        println!(
            "  {:<10} batch {:<4} input {:?}  {} tensors / {} scalars",
            info.name,
            info.batch,
            info.input_shape,
            info.params.len(),
            info.n_scalars,
        );
    }
    let dir = artifacts_dir(args);
    if dir.join("manifest.json").exists() {
        let m = Manifest::load(&dir)?;
        println!("artifact dir: {} (scale {})", m.dir.display(), m.scale);
        for model in &m.models {
            println!(
                "  {:<10} batch {:<4} input {:?}  {} tensors / {} scalars  pallas={}",
                model.name,
                model.batch,
                model.input_shape,
                model.params.len(),
                model.n_scalars,
                model.use_pallas
            );
        }
    } else {
        println!("(no artifact manifest at {}; pjrt backend unavailable)", dir.display());
    }
    Ok(())
}

/// Load + preprocess a dataset per the paper's pipeline for that corpus.
pub fn prepare_data(corpus: Corpus, args: &Args, seed: u64) -> Result<(SplitData, bool)> {
    let opts = protocol::DataOpts {
        data_dir: args.opt_str("data-dir").map(PathBuf::from),
        n_train: args.usize("n-train", 0),
        n_test: args.usize("n-test", 0),
        zca: !args.bool("no-zca", false),
        zca_samples: args.usize("zca-samples", 4000),
        zca_eps: args.f32("zca-eps", 3.0) as f64,
        seed,
    };
    protocol::prepare(corpus, &opts)
}

pub fn opts_from_args(args: &Args) -> Result<TrainOpts> {
    let epochs = args.usize("epochs", 20);
    let lr_start = args.f32("lr-start", 0.02);
    let lr_end = args.f32("lr-end", lr_start * 0.1);
    Ok(TrainOpts {
        epochs,
        schedule: LrSchedule::Exponential { start: lr_start, end: lr_end, epochs },
        mode: Mode::parse(&args.str("mode", "det")).ok_or_else(|| anyhow!("bad --mode"))?,
        opt: Opt::parse(&args.str("opt", "sgd")).ok_or_else(|| anyhow!("bad --opt"))?,
        momentum: args.f32("momentum", 0.9),
        beta2: args.f32("beta2", 0.999),
        eps: args.f32("eps", 1e-8),
        dropout: args.f32("dropout", 0.0),
        in_dropout: args.f32("in-dropout", 0.0),
        bn_momentum: args.f32("bn-momentum", 0.9),
        lr_scale: !args.bool("no-lr-scale", false),
        seed: args.u64("seed", 1),
        patience: args.usize("patience", 0),
        verbose: !args.bool("quiet", false),
        eval_override: args.opt_str("eval-mode").and_then(|s| Mode::parse(&s)),
        checkpoint: CheckpointOpts {
            dir: args.opt_str("checkpoint-dir").map(PathBuf::from),
            every_epochs: args.usize("checkpoint-every-epochs", 1),
            keep: args.usize("keep", 3),
            // a bare `--resume` parses as "true": treat it like `latest`
            resume: args.opt_str("resume").map(|s| match s.as_str() {
                "true" | "latest" => ResumeFrom::Latest,
                _ => ResumeFrom::Path(PathBuf::from(s)),
            }),
        },
        max_diverged_steps: args.usize("max-diverged-steps", 0),
        skip_diverged: !args.bool("no-skip-diverged", false),
        faults: None, // cmd_train/cmd_export wire the shared plan in
        stop: None,
    })
}

/// Parse BCRUN_FAULTS once (fail fast on typos — a chaos run with a
/// silently-ignored spec would "pass" by injecting nothing) and set up
/// the SIGTERM-to-stop-latch bridge shared by train/export runs.
fn train_harness(
    opts: &mut TrainOpts,
) -> Result<Option<std::sync::Arc<binaryconnect::util::FaultPlan>>> {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let faults = binaryconnect::util::FaultPlan::from_env().map_err(|e| anyhow!(e))?.map(Arc::new);
    if let Some(plan) = &faults {
        eprintln!("bcrun train: FAULT INJECTION ACTIVE ({})", plan.summary());
    }
    opts.faults = faults.clone();

    let stop = Arc::new(AtomicBool::new(false));
    opts.stop = Some(stop.clone());
    binaryconnect::serve::signal::install();
    std::thread::spawn(move || loop {
        if binaryconnect::serve::signal::triggered() {
            stop.store(true, Ordering::SeqCst);
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    });
    Ok(faults)
}

/// Post-run reporting shared by train/export: divergence/rollback
/// counters when anything fired, and the resume hint on interruption.
fn report_run_health(result: &binaryconnect::coordinator::RunResult, opts: &TrainOpts) {
    if result.diverged_steps > 0 || result.rollbacks > 0 {
        eprintln!(
            "divergence: {} non-finite steps, {} rollbacks",
            result.diverged_steps, result.rollbacks
        );
    }
    if result.interrupted {
        let hint = match &opts.checkpoint.dir {
            Some(d) => format!("resume with --resume latest --checkpoint-dir {}", d.display()),
            None => "no --checkpoint-dir was set, so progress was not saved".to_string(),
        };
        eprintln!("interrupted by stop signal after {} epochs; {hint}", result.curves.len());
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut opts = opts_from_args(args)?;
    let faults = train_harness(&mut opts)?;
    let model = load_executor(args, faults)?;
    let info = model.info().clone();
    let corpus = Corpus::parse(&args.str("dataset", "mnist"))
        .ok_or_else(|| anyhow!("bad --dataset"))?;

    let (data, real) = prepare_data(corpus, args, opts.seed)?;
    eprintln!(
        "dataset: {} ({} train / {} val / {} test, {})",
        data.train.name,
        data.train.len(),
        data.val.len(),
        data.test.len(),
        if real { "real files" } else { "synthetic" }
    );
    ensure!(
        data.train.dim == info.input_dim(),
        "model {} expects {} features, dataset has {}",
        info.name,
        info.input_dim(),
        data.train.dim
    );

    let result = train(model.as_ref(), &data, &opts)?;
    report_run_health(&result, &opts);

    println!(
        "mode={} opt={} epochs={} -> best val err {:.4} (epoch {}), test err {:.4}, {} steps in {:.1}s",
        opts.mode.label(),
        opts.opt.label(),
        result.curves.len(),
        result.best_val_err,
        result.best_epoch,
        result.test_err,
        result.steps,
        result.total_seconds
    );

    if let Some(path) = args.opt_str("curves") {
        let mut csv = Csv::new(&["epoch", "lr", "train_loss", "train_err", "val_err"]);
        for r in &result.curves {
            csv.rowf(&[r.epoch as f64, r.lr as f64, r.train_loss, r.train_err, r.val_err]);
        }
        csv.save(&PathBuf::from(&path))?;
        eprintln!("wrote {path}");
    }
    if let Some(path) = args.opt_str("histogram") {
        // Figure 2 plots w/H in [-1, 1]; real weights live in ±H with H
        // the layer's Glorot coefficient.
        let h_scale = info.params[0].glorot.max(1e-12) as f32;
        let w0: Vec<f32> =
            result.state.param_vec(0)?.iter().map(|v| v / h_scale).collect();
        let h = Histogram::build(&w0, -1.0, 1.0, 40);
        std::fs::write(&path, h.to_csv())?;
        eprintln!("wrote {path}");
    }
    if let Some(path) = args.opt_str("features") {
        let w0 = result.state.param_vec(0)?;
        let in_dim = info.params[0].shape[0];
        let units = info.params[0].shape[1];
        let side = (in_dim as f64).sqrt() as usize;
        if side * side == in_dim {
            let (img, w, h) = feature_tiles(&w0, in_dim, units, side, 100, 10);
            write_pgm(&PathBuf::from(&path), &img, w, h)?;
            eprintln!("wrote {path}");
        } else {
            eprintln!("features: input dim {in_dim} is not square; skipped");
        }
    }
    Ok(())
}

/// Train (det-BC), then fold + pack the binary weights into a .bcpack file
/// servable by the multiplication-free engine (paper Sec. 2.6 method 1).
fn cmd_export(args: &Args) -> Result<()> {
    use binaryconnect::binary::{pack_mlp, save_packed};

    let mut opts = opts_from_args(args)?;
    opts.mode = Mode::Det; // packed export is the deterministic test-time path
    let faults = train_harness(&mut opts)?;
    let model = load_executor(args, faults)?;
    let info = model.info().clone();
    let corpus = Corpus::parse(&args.str("dataset", "mnist"))
        .ok_or_else(|| anyhow!("bad --dataset"))?;

    let (data, _) = prepare_data(corpus, args, opts.seed)?;
    let result = train(model.as_ref(), &data, &opts)?;
    report_run_health(&result, &opts);
    if result.interrupted {
        // the run checkpointed and exited early: packing a half-trained
        // net would clobber a good .bcpack, so stop here
        eprintln!("export: skipping pack of the interrupted run");
        return Ok(());
    }
    eprintln!("trained: test err {:.4}", result.test_err);

    let packed = pack_mlp(&info, &result.state)?;
    let out = args.str("out", "model.bcpack");
    save_packed(&packed, std::path::Path::new(&out))?;
    println!(
        "wrote {out}: {} layers, {} weight bytes packed ({}x smaller than f32)",
        packed.layers.len(),
        packed.weight_memory_bytes(),
        packed.f32_weight_memory_bytes() / packed.weight_memory_bytes().max(1)
    );
    Ok(())
}

/// Serve a .bcpack model on a test set with the packed engine.
fn cmd_infer(args: &Args) -> Result<()> {
    use binaryconnect::binary::load_packed;
    use binaryconnect::util::Timer;

    let path = args.str("packed", "model.bcpack");
    let packed = load_packed(std::path::Path::new(&path))?;
    let corpus = Corpus::parse(&args.str("dataset", "mnist"))
        .ok_or_else(|| anyhow!("bad --dataset"))?;
    let (data, real) = prepare_data(corpus, args, args.u64("seed", 1))?;
    ensure!(
        data.test.dim == packed.in_dim,
        "model expects {} features, dataset has {}",
        packed.in_dim,
        data.test.dim
    );
    let t = Timer::start();
    let err = packed.test_error(&data.test, args.usize("batch", 256));
    let dt = t.elapsed_s();
    println!(
        "{}: {} test examples ({}) -> err {:.4}, {:.0} img/s, {} weight bytes, zero weight-loop multiplications",
        path,
        data.test.len(),
        if real { "real" } else { "synthetic" },
        err,
        data.test.len() as f64 / dt,
        packed.weight_memory_bytes(),
    );
    Ok(())
}

/// Serve a .bcpack model over HTTP with dynamic micro-batching (paper
/// Sec. 2.6 inference, made an online workload — see DESIGN.md "Serving
/// layer").
fn cmd_serve(args: &Args) -> Result<()> {
    use binaryconnect::binary::{load_packed, ForwardMode};
    use binaryconnect::kernel::simd;
    use binaryconnect::serve;
    use std::time::Duration;

    let path = args.str("packed", "model.bcpack");
    let packed = load_packed(std::path::Path::new(&path))?;
    let port = args.usize("port", 7878);
    ensure!(port <= u16::MAX as usize, "--port {port} is out of range");
    let default_workers =
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).clamp(2, 64);
    let mode = if args.bool("bnn", false) { ForwardMode::Bnn } else { ForwardMode::PackedF32 };
    // fail fast on an unparseable BCRUN_FAULTS: a chaos run with a silent
    // typo in the spec would "pass" by injecting nothing
    let faults = binaryconnect::util::FaultPlan::from_env()
        .map_err(|e| anyhow!(e))?
        .map(std::sync::Arc::new);
    let deadline_ms = args.u64("default-deadline-ms", 0);
    let cfg = serve::ServeConfig {
        addr: args.str("addr", "127.0.0.1"),
        port: port as u16,
        max_batch: args.usize("max-batch", 64),
        max_wait: Duration::from_micros(args.u64("max-wait-us", 200)),
        queue_cap: args.usize("queue-cap", 1024),
        workers: args.usize("workers", default_workers),
        quiet: args.bool("quiet", false),
        mode,
        default_deadline: (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms)),
        faults: faults.clone(),
        ..Default::default()
    };
    let quiet = cfg.quiet;
    let summary = format!(
        "model {} ({} -> {} classes, {} layers, {} packed weight bytes, {} activation bytes) mode={} isa={}",
        path,
        packed.in_dim,
        packed.classes,
        packed.layers.len(),
        packed.weight_memory_bytes(),
        packed.activation_memory_bytes(cfg.max_batch, mode),
        mode.label(),
        simd::active().name(),
    );
    serve::signal::install();
    let mut server = serve::start(packed, cfg)?;
    println!("bcrun serve: listening on http://{}", server.addr());
    if !quiet {
        eprintln!("bcrun serve: {summary}");
        if let Some(plan) = &faults {
            eprintln!("bcrun serve: FAULT INJECTION ACTIVE ({})", plan.summary());
        }
    }
    if let Some(pf) = args.opt_str("port-file") {
        // written after bind so a watcher can poll for the ephemeral port
        std::fs::write(&pf, server.addr().port().to_string())
            .with_context(|| format!("write {pf}"))?;
    }
    while !server.is_shutdown() && !serve::signal::triggered() {
        std::thread::sleep(Duration::from_millis(50));
    }
    if !quiet {
        eprintln!("bcrun serve: shutdown requested; draining in-flight batches");
    }
    server.stop();
    let snap = server.metrics().snapshot(0);
    println!(
        "bcrun serve: done — {} requests, {} predictions in {} batches (mean batch {:.2}), p50 {:.0} us, p99 {:.0} us",
        snap.get("requests").and_then(|j| j.as_usize()).unwrap_or(0),
        snap.get("predictions").and_then(|j| j.as_usize()).unwrap_or(0),
        snap.get("batches").and_then(|j| j.as_usize()).unwrap_or(0),
        snap.get("mean_batch_rows").and_then(|j| j.as_f64()).unwrap_or(0.0),
        snap.get("latency_p50_us").and_then(|j| j.as_f64()).unwrap_or(0.0),
        snap.get("latency_p99_us").and_then(|j| j.as_f64()).unwrap_or(0.0),
    );
    let restarts = |k: &str| snap.get(k).and_then(|j| j.as_usize()).unwrap_or(0);
    let (wr, br, ds) =
        (restarts("worker_restarts"), restarts("batcher_restarts"), restarts("deadline_sheds_504"));
    if wr + br + ds > 0 {
        println!(
            "bcrun serve: supervision — {wr} worker restarts, {br} batcher restarts, {ds} deadline sheds (504)"
        );
    }
    Ok(())
}

/// Closed-loop load test against a running `bcrun serve`.
fn cmd_loadgen(args: &Args) -> Result<()> {
    use binaryconnect::serve::loadgen;

    let url = args.str("url", "http://127.0.0.1:7878");
    let opts = loadgen::LoadgenOpts {
        host: loadgen::host_of(&url)?,
        concurrency: args.usize("concurrency", 16),
        requests: args.usize("requests", 1000),
        seed: args.u64("seed", 1),
        retries: args.usize("retries", 3),
    };
    let rep = loadgen::run(&opts)?;
    println!(
        "loadgen: {} requests ({} ok, {} non-2xx, {} transport errors, {} retries) in {:.2}s from {} connections",
        rep.sent, rep.ok, rep.failed_status, rep.errors, rep.retries, rep.elapsed_s, opts.concurrency
    );
    println!(
        "  throughput {:.0} req/s | latency p50 {:.0} us, p95 {:.0} us, p99 {:.0} us, max {:.0} us | server mean batch {:.2}",
        rep.throughput_rps(),
        rep.latency.percentile(50.0) * 1e6,
        rep.latency.percentile(95.0) * 1e6,
        rep.latency.percentile(99.0) * 1e6,
        rep.latency.max() * 1e6,
        rep.server_mean_batch,
    );
    ensure!(
        rep.failed_status == 0 && rep.errors == 0,
        "load test saw {} non-2xx responses and {} transport errors",
        rep.failed_status,
        rep.errors
    );
    Ok(())
}

fn cmd_hw(args: &Args) -> Result<()> {
    let model_name = args.str("model", "mlp");
    let info = model_spec(args, &model_name)?;
    let batch = args.usize("batch", info.batch) as u64;

    // spatial sizes for the CNN's conv layers come from the shared
    // shape inference (conv::spatial_dims) — the same SAME-conv /
    // MP2-after-every-second-conv schedule the runtime plan and the
    // packed exporter use, instead of a duplicated hardcoded ladder
    let conv_dims = binaryconnect::conv::spatial_dims(&info)?;
    let hw_of = |name: &str| -> u64 {
        conv_dims
            .iter()
            .find(|d| d.name == name)
            .map(|d| d.spatial() as u64)
            .unwrap_or(1)
    };

    let real = hw::step_cost(&info.params, batch, false, hw_of);
    let bc = hw::step_cost(&info.params, batch, true, hw_of);
    println!("model {model_name}, batch {batch} — per-step op counts:");
    println!(
        "  conventional: {:>14} mults  {:>14} adds",
        real.total_mults(),
        real.total_adds()
    );
    println!(
        "  BinaryConnect:{:>14} mults  {:>14} adds",
        bc.total_mults(),
        bc.total_adds()
    );
    println!(
        "  multiplications removed: {:.1}% (paper: ~66.7%)",
        100.0 * hw::mult_reduction(&real, &bc)
    );
    let mem = hw::weight_memory(&info.params);
    println!(
        "  test-time weight memory: f32 {} KiB -> packed {} KiB ({}x; paper claims >= 16x vs 16-bit = {}x)",
        mem.f32_bytes / 1024,
        mem.packed_bytes / 1024,
        mem.f32_bytes / mem.packed_bytes.max(1),
        mem.f16_bytes / mem.packed_bytes.max(1),
    );
    Ok(())
}
