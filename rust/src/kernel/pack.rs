//! Operand panel packing for the f32 GEMM trio (tract/BLIS lineage).
//!
//! The panel kernels in `kernel/gemm.rs` never touch A or B directly:
//! both operands are first repacked into the microkernel's native layout,
//! so the innermost loop streams two contiguous buffers regardless of the
//! transposition variant, and every ragged edge is handled *here*, once,
//! by zero padding.
//!
//! ## Layout
//!
//! * **LHS** (`pack_lhs`): A is cut into `mr`-row panels. Panel `p` holds
//!   rows `p*mr .. p*mr+mr`, stored k-major with the `mr` rows
//!   interleaved: `pa[(p*k + kk)*mr + r] = A[p*mr + r, kk]`. One k-step of
//!   the microkernel therefore loads `mr` consecutive floats.
//! * **RHS** (`pack_rhs`): B is cut into `nr`-column panels, also k-major:
//!   `pb[(q*k + kk)*nr + j] = B[kk, q*nr + j]`. One k-step loads `nr`
//!   consecutive floats.
//!
//! Because both layouts are k-major *within* a panel, any k-block
//! `kc0..kc1` of a panel is itself contiguous — the cache-blocked loop
//! nest slices packed panels, it never re-packs.
//!
//! Rows beyond `m` (and columns beyond `n`) in the last panel are filled
//! with `0.0`, so the microkernel always computes a full `mr x nr` tile;
//! the driver merges only the valid sub-rectangle back into C.
//!
//! Sources are described by `(row stride, col stride)` pairs, which is how
//! all three GEMM orientations (`A·B`, `Aᵀ·B`, `A·Bᵀ`) share these two
//! packers: a transposed operand just swaps its strides.
//!
//! Buffers are caller-owned ([`PanelBuf`]), grow-only, and reused — the
//! training step packs into workspace storage sized once at build time, so
//! the warmed-up step stays allocation-free.

use super::simd::{MR_MAX, NR_MAX};

/// Packed length of an `m x k` LHS for `mr`-row panels.
pub fn lhs_len(m: usize, k: usize, mr: usize) -> usize {
    m.div_ceil(mr) * k * mr
}

/// Packed length of a `k x n` RHS for `nr`-column panels.
pub fn rhs_len(k: usize, n: usize, nr: usize) -> usize {
    n.div_ceil(nr) * k * nr
}

/// Caller-owned, reusable packing storage for one GEMM at a time (an LHS
/// area and an RHS area). Grow-only: `reserve_gemm` at build time makes
/// every later [`ensure`](PanelBuf::ensure) a no-op, which is what keeps
/// the train-step's counting-allocator test at zero.
#[derive(Default)]
pub struct PanelBuf {
    pa: Vec<f32>,
    pb: Vec<f32>,
}

impl PanelBuf {
    pub fn new() -> PanelBuf {
        PanelBuf::default()
    }

    /// Grow (never shrink) the two areas to at least the given lengths.
    pub fn ensure(&mut self, pa_len: usize, pb_len: usize) {
        if self.pa.len() < pa_len {
            self.pa.resize(pa_len, 0.0);
        }
        if self.pb.len() < pb_len {
            self.pb.resize(pb_len, 0.0);
        }
    }

    /// Presize for a logical `C[m x n] = L[m x k] @ R[k x n]` product under
    /// the widest microkernel geometry any ISA uses (`MR_MAX` x `NR_MAX`),
    /// so the actual rung's `ensure` can only ask for less.
    pub fn reserve_gemm(&mut self, m: usize, k: usize, n: usize) {
        self.ensure(lhs_len(m, k, MR_MAX), rhs_len(k, n, NR_MAX));
    }

    /// The two packing areas, sized exactly, borrowed simultaneously.
    pub(super) fn views(&mut self, pa_len: usize, pb_len: usize) -> (&mut [f32], &mut [f32]) {
        (&mut self.pa[..pa_len], &mut self.pb[..pb_len])
    }
}

/// Pack LHS panels `plo..phi` of the logical `m x k` matrix whose element
/// `(i, kk)` lives at `src[i*rs + kk*cs]`. `dst` holds exactly those
/// panels (`(phi-plo)*k*mr` floats); rows past `m` are zero-filled.
#[allow(clippy::too_many_arguments)]
pub fn pack_lhs(
    src: &[f32],
    rs: usize,
    cs: usize,
    m: usize,
    k: usize,
    mr: usize,
    plo: usize,
    phi: usize,
    dst: &mut [f32],
) {
    assert_eq!(dst.len(), (phi - plo) * k * mr, "pack_lhs: dst length");
    for (dp, panel) in dst.chunks_exact_mut(k * mr).enumerate() {
        let i0 = (plo + dp) * mr;
        let il = mr.min(m - i0.min(m));
        for (kk, d) in panel.chunks_exact_mut(mr).enumerate() {
            if cs == 1 && il == mr {
                // contiguous source rows in k (the Aᵀ·B orientation packs
                // k-contiguous *columns* of A, i.e. rs == 1 below instead)
                for (r, dv) in d.iter_mut().enumerate() {
                    *dv = src[(i0 + r) * rs + kk];
                }
            } else if rs == 1 && il == mr {
                d.copy_from_slice(&src[i0 + kk * cs..i0 + kk * cs + mr]);
            } else {
                for (r, dv) in d.iter_mut().enumerate() {
                    *dv = if r < il { src[(i0 + r) * rs + kk * cs] } else { 0.0 };
                }
            }
        }
    }
}

/// Pack RHS panels `qlo..qhi` of the logical `k x n` matrix whose element
/// `(kk, j)` lives at `src[kk*rs + j*cs]`. `dst` holds exactly those
/// panels (`(qhi-qlo)*k*nr` floats); columns past `n` are zero-filled.
#[allow(clippy::too_many_arguments)]
pub fn pack_rhs(
    src: &[f32],
    rs: usize,
    cs: usize,
    k: usize,
    n: usize,
    nr: usize,
    qlo: usize,
    qhi: usize,
    dst: &mut [f32],
) {
    assert_eq!(dst.len(), (qhi - qlo) * k * nr, "pack_rhs: dst length");
    for (dq, panel) in dst.chunks_exact_mut(k * nr).enumerate() {
        let j0 = (qlo + dq) * nr;
        let jl = nr.min(n - j0.min(n));
        for (kk, d) in panel.chunks_exact_mut(nr).enumerate() {
            if cs == 1 && jl == nr {
                d.copy_from_slice(&src[kk * rs + j0..kk * rs + j0 + nr]);
            } else {
                for (j, dv) in d.iter_mut().enumerate() {
                    *dv = if j < jl { src[kk * rs + (j0 + j) * cs] } else { 0.0 };
                }
            }
        }
    }
}

/// Inverse of [`pack_lhs`] over all panels: reconstruct the logical
/// row-major `m x k` matrix. Test support for the roundtrip property
/// suite; padding lanes are dropped.
pub fn unpack_lhs(pa: &[f32], m: usize, k: usize, mr: usize) -> Vec<f32> {
    assert!(pa.len() >= lhs_len(m, k, mr), "unpack_lhs: packed buffer too short");
    let mut out = vec![0f32; m * k];
    for p in 0..m.div_ceil(mr) {
        let i0 = p * mr;
        for kk in 0..k {
            let d = &pa[(p * k + kk) * mr..(p * k + kk + 1) * mr];
            for (r, &v) in d.iter().enumerate().take(m - i0.min(m)).take(mr) {
                out[(i0 + r) * k + kk] = v;
            }
        }
    }
    out
}

/// Inverse of [`pack_rhs`] over all panels: reconstruct the logical
/// row-major `k x n` matrix. Test support; padding lanes are dropped.
pub fn unpack_rhs(pb: &[f32], k: usize, n: usize, nr: usize) -> Vec<f32> {
    assert!(pb.len() >= rhs_len(k, n, nr), "unpack_rhs: packed buffer too short");
    let mut out = vec![0f32; k * n];
    for q in 0..n.div_ceil(nr) {
        let j0 = q * nr;
        for kk in 0..k {
            let d = &pb[(q * k + kk) * nr..(q * k + kk + 1) * nr];
            for (j, &v) in d.iter().enumerate().take(n - j0.min(n)).take(nr) {
                out[kk * n + j0 + j] = v;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..len).map(|_| rng.normal()).collect()
    }

    #[test]
    fn lhs_roundtrip_and_padding() {
        for (m, k, mr) in [(1, 1, 4), (4, 5, 4), (5, 3, 4), (13, 7, 4), (8, 6, 4)] {
            let a = rand(m * k, 7 + m as u64);
            let mut pa = vec![f32::NAN; lhs_len(m, k, mr)];
            pack_lhs(&a, k, 1, m, k, mr, 0, m.div_ceil(mr), &mut pa);
            assert_eq!(unpack_lhs(&pa, m, k, mr), a, "m={m} k={k}");
            // padding rows in the last panel are exactly zero
            let last = m.div_ceil(mr) - 1;
            for kk in 0..k {
                let d = &pa[(last * k + kk) * mr..(last * k + kk + 1) * mr];
                for (r, &v) in d.iter().enumerate() {
                    if last * mr + r >= m {
                        assert_eq!(v, 0.0, "pad row not zero at panel {last} kk={kk} r={r}");
                    }
                }
            }
        }
    }

    #[test]
    fn rhs_roundtrip_and_padding() {
        for (k, n, nr) in [(1, 1, 8), (3, 8, 8), (5, 9, 8), (7, 33, 16), (6, 16, 16)] {
            let b = rand(k * n, 31 + n as u64);
            let mut pb = vec![f32::NAN; rhs_len(k, n, nr)];
            pack_rhs(&b, n, 1, k, n, nr, 0, n.div_ceil(nr), &mut pb);
            assert_eq!(unpack_rhs(&pb, k, n, nr), b, "k={k} n={n}");
            let last = n.div_ceil(nr) - 1;
            for kk in 0..k {
                let d = &pb[(last * k + kk) * nr..(last * k + kk + 1) * nr];
                for (j, &v) in d.iter().enumerate() {
                    if last * nr + j >= n {
                        assert_eq!(v, 0.0, "pad col not zero at panel {last} kk={kk} j={j}");
                    }
                }
            }
        }
    }

    #[test]
    fn strided_packs_match_explicit_transpose() {
        let (m, k) = (6, 5);
        let a = rand(m * k, 99);
        // Aᵀ as an LHS: logical k x m matrix with rs=1, cs=k
        let mut at = vec![0f32; k * m];
        for i in 0..m {
            for kk in 0..k {
                at[kk * m + i] = a[i * k + kk];
            }
        }
        let mr = 4;
        let mut via_stride = vec![0f32; lhs_len(k, m, mr)];
        pack_lhs(&a, 1, k, k, m, mr, 0, k.div_ceil(mr), &mut via_stride);
        let mut via_dense = vec![0f32; lhs_len(k, m, mr)];
        pack_lhs(&at, m, 1, k, m, mr, 0, k.div_ceil(mr), &mut via_dense);
        assert_eq!(via_stride, via_dense);
        // Bᵀ as an RHS: logical k x m matrix of b (m x k) with rs=1, cs=k
        let nr = 8;
        let mut rvia_stride = vec![0f32; rhs_len(k, m, nr)];
        pack_rhs(&a, 1, k, k, m, nr, 0, m.div_ceil(nr), &mut rvia_stride);
        let mut rvia_dense = vec![0f32; rhs_len(k, m, nr)];
        pack_rhs(&at, m, 1, k, m, nr, 0, m.div_ceil(nr), &mut rvia_dense);
        assert_eq!(rvia_stride, rvia_dense);
    }

    #[test]
    fn panel_ranges_compose() {
        // packing panels [0,2) and [2,np) separately equals one pass
        let (m, k, mr) = (11, 9, 4);
        let a = rand(m * k, 5);
        let np = m.div_ceil(mr);
        let mut whole = vec![0f32; lhs_len(m, k, mr)];
        pack_lhs(&a, k, 1, m, k, mr, 0, np, &mut whole);
        let mut parts = vec![0f32; lhs_len(m, k, mr)];
        let (lo, hi) = parts.split_at_mut(2 * k * mr);
        pack_lhs(&a, k, 1, m, k, mr, 0, 2, lo);
        pack_lhs(&a, k, 1, m, k, mr, 2, np, hi);
        assert_eq!(whole, parts);
    }

    #[test]
    fn panel_buf_is_grow_only() {
        let mut buf = PanelBuf::new();
        buf.reserve_gemm(100, 1024, 1024);
        let (pa, pb) = buf.views(lhs_len(100, 1024, 4), rhs_len(1024, 1024, 16));
        let (la, lb) = (pa.len(), pb.len());
        buf.reserve_gemm(10, 10, 10); // smaller: must not shrink
        buf.ensure(la, lb); // equal: must not move
        let (pa2, pb2) = buf.views(la, lb);
        assert_eq!(pa2.len(), la);
        assert_eq!(pb2.len(), lb);
    }
}
