//! The crate's hot-path kernel layer.
//!
//! One home for every dense f32 GEMM the training loop, the preprocessing
//! pipeline and the packed engine touch. The layer is panel-packed
//! (tract/BLIS lineage): [`pack`] repacks both operands into the active
//! microkernel's mr-row / nr-column panel layout, and one loop nest
//! ([`gemm`]'s driver) runs the register-tiled panel kernel from the
//! [`simd`] dispatch table over contiguous packed memory. All three
//! transposition variants (`A·B`, `Aᵀ·B`, `A·Bᵀ`) are stride pairs into
//! the same packer, so ragged edges are handled once, by zero padding.
//!
//! Entry-point families per operation:
//!
//! * `gemm*`          — panel-packed, parallelized over output-row panels
//!   on the [`util::pool`](crate::util::pool) thread pool, packing into a
//!   thread-local buffer. The default everywhere.
//! * `gemm*_into`     — same kernel, packing into a caller-owned
//!   [`PanelBuf`]; the train-step workspace presizes one so the warmed-up
//!   step allocates nothing.
//! * `gemm*_serial`   — one thread, **bit-for-bit identical** to the
//!   pooled variant (per output element the k-blocks and the microkernel
//!   reduction order are fixed, independent of the thread split), which
//!   the `prop_invariants` suite pins down.
//! * `gemm*_with`     — explicit ISA rung, for tests and the `perf_gemm`
//!   dispatch ladder (no process-global mutation).
//! * `gemm*_strip`    — the pre-panel 4-row strip kernels, serial: the
//!   baseline of `perf_gemm`'s `panel_speedup_vs_strip` series and a
//!   second oracle.
//! * `gemm*_naive`    — the seed's loops, the correctness oracle.
//!
//! All kernels write into caller-provided `&mut [f32]` buffers so the
//! training loop can run allocation-free out of its per-executor
//! workspace (`runtime/reference.rs`); the bit-packed sign kernels live
//! with their data layout in `binary/packed.rs`.
//!
//! The [`simd`] table carries AVX2+FMA or SSE2 microkernels on x86_64,
//! NEON on aarch64 (runtime-detected, `BCRUN_SIMD`-overridable), scalar
//! everywhere else.

mod gemm;
pub mod pack;
pub mod simd;

pub use gemm::{
    gemm, gemm_a_bt, gemm_a_bt_into, gemm_a_bt_naive, gemm_a_bt_serial, gemm_a_bt_strip,
    gemm_a_bt_with, gemm_at_b, gemm_at_b_into, gemm_at_b_naive, gemm_at_b_serial, gemm_at_b_strip,
    gemm_at_b_with, gemm_into, gemm_naive, gemm_serial, gemm_strip, gemm_with,
};
pub use pack::PanelBuf;
