//! The crate's hot-path kernel layer.
//!
//! One home for every dense f32 GEMM the training loop, the preprocessing
//! pipeline and the packed engine touch (previously duplicated between
//! `preprocess::linalg` and `binary::packed::dense_f32`). Three variants
//! per operation:
//!
//! * `gemm*`          — register-blocked, cache-tiled, parallelized over
//!   output-row blocks on the [`util::pool`](crate::util::pool) thread
//!   pool. The default everywhere.
//! * `gemm*_serial`   — the same blocked kernel on one thread. Per output
//!   row the two are **bit-for-bit identical** (rows never split across
//!   threads and the reduction order per row is fixed), which the
//!   `prop_invariants` suite pins down.
//! * `gemm*_naive`    — the seed's allocation-era loops, kept as the
//!   correctness oracle and as the honest "current main" baseline the
//!   `perf_gemm` bench measures speedups against.
//!
//! All kernels write into caller-provided `&mut [f32]` buffers so the
//! training loop can run allocation-free out of its per-executor
//! workspace (`runtime/reference.rs`); the bit-packed sign kernels live
//! with their data layout in `binary/packed.rs`.
//!
//! Beneath the blocked/pooled structure, the innermost loops dispatch
//! through the [`simd`] microkernel table — AVX2+FMA or SSE2 on x86_64
//! (runtime-detected, `BCRUN_SIMD`-overridable), scalar elsewhere. The
//! `gemm*_with` variants pin an explicit ISA rung for tests and the
//! `perf_gemm` dispatch ladder.

mod gemm;
pub mod simd;

pub use gemm::{
    gemm, gemm_a_bt, gemm_a_bt_naive, gemm_a_bt_serial, gemm_a_bt_with, gemm_at_b,
    gemm_at_b_naive, gemm_at_b_serial, gemm_at_b_with, gemm_naive, gemm_serial, gemm_with,
};
