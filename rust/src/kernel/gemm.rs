//! Blocked f32 GEMM kernels (C = A·B, A^T·B, A·B^T).
//!
//! Layout is row-major throughout. The blocked kernels tile k and n so the
//! streamed B panel stays cache-resident across output rows, process four
//! output rows per pass to amortize that panel traffic, and keep the
//! seed's zero-skip (activations are ~half zeros after ReLU/dropout, so
//! skipping a zero A value skips a whole vector row update). Parallelism
//! is over disjoint output-row blocks via `util::pool::par_rows`; a row is
//! never split across threads and its (k-tile, n-tile) reduction order is
//! fixed, so results are identical for any thread count.
//!
//! The innermost loops (the 4-row axpy strip, the single-row axpy, the
//! A·B^T dot) go through the runtime-dispatched microkernel table in
//! [`super::simd`]: AVX2+FMA or SSE2 on x86_64, the original scalar loops
//! everywhere else (and under `BCRUN_SIMD=scalar`). Pooled and serial
//! variants fetch the same table, so their bit-for-bit equality survives
//! dispatch; the `*_with` variants pin an explicit ISA for tests and the
//! `perf_gemm` dispatch-ladder series.

use super::simd::{self, Isa, Kernels};
use crate::util::pool::{global, par_rows, SendPtr};

/// k-tile: the B panel rows kept hot while sweeping output rows.
const KB: usize = 256;
/// n-tile: the B panel width; KB*NB*4 = 256 KiB stays L2-resident.
const NB: usize = 256;
/// i-tile for the outer-product A^T·B kernel's C block.
const IB: usize = 64;
/// Below this many multiply-adds, dispatch overhead beats the pool.
const PAR_MIN_WORK: usize = 1 << 16;

fn row_grain(rows: usize) -> usize {
    let t = global().n_threads;
    rows.div_ceil(t * 4).max(4)
}

// ---------------------------------------------------------------------------
// C[m x n] = A[m x k] @ B[k x n]
// ---------------------------------------------------------------------------

/// Compute rows `lo..hi` of C = A·B into `c` (which holds exactly those
/// rows). Fixed (kb, jb) tile order per row -> thread-count independent.
#[allow(clippy::too_many_arguments)]
fn gemm_rows(
    kern: &Kernels,
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    lo: usize,
    hi: usize,
    c: &mut [f32],
) {
    c.fill(0.0);
    let rows = hi - lo;
    let mut kb = 0;
    while kb < k {
        let ke = (kb + KB).min(k);
        let mut jb = 0;
        while jb < n {
            let je = (jb + NB).min(n);
            let mut r = 0usize;
            // 4-row strips: one B-panel read feeds four C rows.
            while r + 4 <= rows {
                let i = lo + r;
                let (c01, c23) = c[r * n..(r + 4) * n].split_at_mut(2 * n);
                let (c0, c1) = c01.split_at_mut(n);
                let (c2, c3) = c23.split_at_mut(n);
                let c0 = &mut c0[jb..je];
                let c1 = &mut c1[jb..je];
                let c2 = &mut c2[jb..je];
                let c3 = &mut c3[jb..je];
                for p in kb..ke {
                    let a0 = a[i * k + p];
                    let a1 = a[(i + 1) * k + p];
                    let a2 = a[(i + 2) * k + p];
                    let a3 = a[(i + 3) * k + p];
                    if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                        continue;
                    }
                    let br = &b[p * n + jb..p * n + je];
                    (kern.axpy4)(&[a0, a1, a2, a3], br, c0, c1, c2, c3);
                }
                r += 4;
            }
            // tail rows, one at a time (axpy1 ≡ one axpy4 row per ISA, so
            // a row computes the same bits whether it fell in a strip or
            // in the tail of a different pooled split)
            while r < rows {
                let i = lo + r;
                let crow = &mut c[r * n + jb..r * n + je];
                for p in kb..ke {
                    let av = a[i * k + p];
                    if av == 0.0 {
                        continue;
                    }
                    let br = &b[p * n + jb..p * n + je];
                    (kern.axpy1)(av, br, crow);
                }
                r += 1;
            }
            jb = je;
        }
        kb = ke;
    }
}

/// C = A·B, blocked + parallel (the default forward kernel).
pub fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "gemm: A length");
    assert_eq!(b.len(), k * n, "gemm: B length");
    assert_eq!(c.len(), m * n, "gemm: C length");
    let kern = simd::kernels();
    if m * k * n < PAR_MIN_WORK {
        gemm_rows(kern, a, b, k, n, 0, m, c);
        return;
    }
    let cp = SendPtr(c.as_mut_ptr());
    par_rows(m, row_grain(m), &|lo, hi| {
        // SAFETY: par_rows hands out disjoint row ranges of C.
        let rows = unsafe { cp.slice(lo * n, (hi - lo) * n) };
        gemm_rows(kern, a, b, k, n, lo, hi, rows);
    });
}

/// C = A·B, blocked, single-threaded; bit-for-bit equal to [`gemm`].
pub fn gemm_serial(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    gemm_rows(simd::kernels(), a, b, k, n, 0, m, c);
}

/// C = A·B with an explicit ISA rung, single-threaded. Test/bench hook:
/// lets callers compare rungs without touching the global dispatch.
pub fn gemm_with(isa: Isa, a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    gemm_rows(simd::kernels_for(isa), a, b, k, n, 0, m, c);
}

/// The seed's ikj loop (one row of B streamed per A value, zero-skip):
/// correctness oracle and "current main" perf baseline.
pub fn gemm_naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    for (arow, crow) in a.chunks_exact(k).zip(c.chunks_exact_mut(n)) {
        crow.fill(0.0);
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// C[k x n] = A^T @ B   (A is m x k, B is m x n) — the dW = X^T·dZ kernel
// ---------------------------------------------------------------------------

/// Compute C rows `ilo..ihi` (features of A) into `c`. Outer-product form
/// preserves the zero-skip on A (post-ReLU activations): a zero
/// activation skips an entire row update of width NB.
#[allow(clippy::too_many_arguments)]
fn at_b_rows(
    kern: &Kernels,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    ilo: usize,
    ihi: usize,
    c: &mut [f32],
) {
    c.fill(0.0);
    let rows = ihi - ilo;
    let mut jb = 0;
    while jb < n {
        let je = (jb + NB).min(n);
        let mut ib = 0;
        while ib < rows {
            let ie = (ib + IB).min(rows);
            for t in 0..m {
                let arow = &a[t * k + ilo + ib..t * k + ilo + ie];
                let brow = &b[t * n + jb..t * n + je];
                for (r2, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let base = (ib + r2) * n;
                    let crow = &mut c[base + jb..base + je];
                    (kern.axpy1)(av, brow, crow);
                }
            }
            ib = ie;
        }
        jb = je;
    }
}

/// C = A^T·B, blocked + parallel over C-row (feature) blocks.
pub fn gemm_at_b(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k, "gemm_at_b: A length");
    assert_eq!(b.len(), m * n, "gemm_at_b: B length");
    assert_eq!(c.len(), k * n, "gemm_at_b: C length");
    let kern = simd::kernels();
    if m * k * n < PAR_MIN_WORK {
        at_b_rows(kern, a, b, m, k, n, 0, k, c);
        return;
    }
    let cp = SendPtr(c.as_mut_ptr());
    par_rows(k, row_grain(k), &|ilo, ihi| {
        // SAFETY: disjoint C row ranges.
        let rows = unsafe { cp.slice(ilo * n, (ihi - ilo) * n) };
        at_b_rows(kern, a, b, m, k, n, ilo, ihi, rows);
    });
}

/// C = A^T·B, blocked, single-threaded; bit-for-bit equal to [`gemm_at_b`].
pub fn gemm_at_b_serial(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    assert_eq!(c.len(), k * n);
    at_b_rows(simd::kernels(), a, b, m, k, n, 0, k, c);
}

/// C = A^T·B with an explicit ISA rung, single-threaded (test/bench hook).
pub fn gemm_at_b_with(isa: Isa, a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    assert_eq!(c.len(), k * n);
    at_b_rows(simd::kernels_for(isa), a, b, m, k, n, 0, k, c);
}

/// The seed's A^T·B loop (per-sample outer products, zero-skip).
pub fn gemm_at_b_naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), m * n);
    assert_eq!(c.len(), k * n);
    c.fill(0.0);
    for (arow, brow) in a.chunks_exact(k).zip(b.chunks_exact(n)) {
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// C[m x k] = A @ B^T   (A is m x n, B is k x n) — the dX = dZ·W^T kernel
// ---------------------------------------------------------------------------

/// Compute C rows `lo..hi` (batch rows) into `c`; n is tiled so the B rows
/// being dotted stay cache-resident. The dot microkernel has a fixed
/// per-ISA reduction order, so every call site agrees bit-for-bit.
#[allow(clippy::too_many_arguments)]
fn a_bt_rows(
    kern: &Kernels,
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    lo: usize,
    hi: usize,
    c: &mut [f32],
) {
    c.fill(0.0);
    let mut nb = 0;
    while nb < n {
        let ne = (nb + NB).min(n);
        for (r, crow) in c.chunks_exact_mut(k).enumerate() {
            let t = lo + r;
            let arow = &a[t * n + nb..t * n + ne];
            for (i, cv) in crow.iter_mut().enumerate() {
                let brow = &b[i * n + nb..i * n + ne];
                *cv += (kern.dot)(arow, brow);
            }
        }
        nb = ne;
    }
}

/// C = A·B^T, blocked + parallel over C-row (batch) blocks.
pub fn gemm_a_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * n, "gemm_a_bt: A length");
    assert_eq!(b.len(), k * n, "gemm_a_bt: B length");
    assert_eq!(c.len(), m * k, "gemm_a_bt: C length");
    let kern = simd::kernels();
    if m * k * n < PAR_MIN_WORK {
        a_bt_rows(kern, a, b, n, k, 0, m, c);
        return;
    }
    let cp = SendPtr(c.as_mut_ptr());
    par_rows(m, row_grain(m), &|lo, hi| {
        // SAFETY: disjoint C row ranges.
        let rows = unsafe { cp.slice(lo * k, (hi - lo) * k) };
        a_bt_rows(kern, a, b, n, k, lo, hi, rows);
    });
}

/// C = A·B^T, blocked, single-threaded; bit-for-bit equal to [`gemm_a_bt`].
pub fn gemm_a_bt_serial(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * k);
    a_bt_rows(simd::kernels(), a, b, n, k, 0, m, c);
}

/// C = A·B^T with an explicit ISA rung, single-threaded (test/bench hook).
pub fn gemm_a_bt_with(isa: Isa, a: &[f32], b: &[f32], m: usize, n: usize, k: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * k);
    a_bt_rows(simd::kernels_for(isa), a, b, n, k, 0, m, c);
}

/// The seed's A·B^T loop (single-accumulator row dots).
pub fn gemm_a_bt_naive(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * n);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * k);
    for (arow, crow) in a.chunks_exact(n).zip(c.chunks_exact_mut(k)) {
        for (i, cv) in crow.iter_mut().enumerate() {
            let brow = &b[i * n..(i + 1) * n];
            let mut acc = 0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *cv = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand(len: usize, seed: u64, sparsity: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..len)
            .map(|_| if rng.uniform() < sparsity { 0.0 } else { rng.normal() })
            .collect()
    }

    fn close(xs: &[f32], ys: &[f32], tol: f32) {
        assert_eq!(xs.len(), ys.len());
        for (i, (x, y)) in xs.iter().zip(ys).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "[{i}] {x} vs {y}");
        }
    }

    #[test]
    fn blocked_gemm_matches_naive_across_shapes() {
        // shapes straddling the KB/NB tile edges and non-multiples of 4
        for (m, k, n, seed) in
            [(1, 1, 1, 1u64), (3, 5, 7, 2), (7, 257, 300, 3), (100, 256, 256, 4), (13, 300, 9, 5)]
        {
            let a = rand(m * k, seed, 0.4);
            let b = rand(k * n, seed + 50, 0.0);
            let mut want = vec![0f32; m * n];
            gemm_naive(&a, &b, m, k, n, &mut want);
            let mut got = vec![0f32; m * n];
            gemm(&a, &b, m, k, n, &mut got);
            close(&got, &want, 1e-4);
            let mut st = vec![0f32; m * n];
            gemm_serial(&a, &b, m, k, n, &mut st);
            assert_eq!(st, got, "pooled vs serial must be bit-identical");
        }
    }

    #[test]
    fn blocked_at_b_matches_naive() {
        for (m, k, n, seed) in [(4, 6, 3, 10u64), (33, 300, 70, 11), (64, 128, 257, 12)] {
            let a = rand(m * k, seed, 0.5);
            let b = rand(m * n, seed + 50, 0.0);
            let mut want = vec![0f32; k * n];
            gemm_at_b_naive(&a, &b, m, k, n, &mut want);
            let mut got = vec![0f32; k * n];
            gemm_at_b(&a, &b, m, k, n, &mut got);
            close(&got, &want, 1e-4);
            let mut st = vec![0f32; k * n];
            gemm_at_b_serial(&a, &b, m, k, n, &mut st);
            assert_eq!(st, got);
        }
    }

    #[test]
    fn blocked_a_bt_matches_naive() {
        for (m, n, k, seed) in [(5, 9, 4, 20u64), (40, 300, 33, 21), (64, 257, 128, 22)] {
            let a = rand(m * n, seed, 0.0);
            let b = rand(k * n, seed + 50, 0.0);
            let mut want = vec![0f32; m * k];
            gemm_a_bt_naive(&a, &b, m, n, k, &mut want);
            let mut got = vec![0f32; m * k];
            gemm_a_bt(&a, &b, m, n, k, &mut got);
            close(&got, &want, 1e-4);
            let mut st = vec![0f32; m * k];
            gemm_a_bt_serial(&a, &b, m, n, k, &mut st);
            assert_eq!(st, got);
        }
    }

    #[test]
    fn kernels_overwrite_stale_output() {
        // C buffers are reused across steps by the workspace; every kernel
        // must fully overwrite, never accumulate into, stale contents.
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 4.0];
        let mut c = vec![99.0f32];
        gemm(&a, &b, 1, 2, 1, &mut c);
        assert_eq!(c, vec![11.0]);
        let mut c2 = vec![99.0f32, 99.0, 99.0, 99.0];
        gemm_at_b(&a, &b, 1, 2, 2, &mut c2); // A 1x2, B 1x2 -> C 2x2
        assert_eq!(c2, vec![3.0, 4.0, 6.0, 8.0]);
        let mut c3 = vec![99.0f32];
        gemm_a_bt(&a, &b, 1, 2, 1, &mut c3); // A 1x2, B 1x2 -> C 1x1
        assert_eq!(c3, vec![11.0]);
    }

    #[test]
    fn explicit_isa_variants_match_active_dispatch() {
        // gemm_with(active) must equal gemm_serial (same table, same
        // single-threaded path) — the hook is a pinning, not a fork.
        let isa = simd::active();
        let (m, k, n) = (7, 130, 65);
        let a = rand(m * k, 31, 0.3);
        let b = rand(k * n, 32, 0.0);
        let mut via_serial = vec![0f32; m * n];
        gemm_serial(&a, &b, m, k, n, &mut via_serial);
        let mut via_with = vec![0f32; m * n];
        gemm_with(isa, &a, &b, m, k, n, &mut via_with);
        assert_eq!(via_serial, via_with);
        let b2 = rand(m * n, 33, 0.0);
        let mut s = vec![0f32; k * n];
        gemm_at_b_serial(&a, &b2, m, k, n, &mut s);
        let mut w = vec![0f32; k * n];
        gemm_at_b_with(isa, &a, &b2, m, k, n, &mut w);
        assert_eq!(s, w);
        let a2 = rand(m * n, 34, 0.0);
        let b3 = rand(k * n, 35, 0.0);
        let mut s = vec![0f32; m * k];
        gemm_a_bt_serial(&a2, &b3, m, n, k, &mut s);
        let mut w = vec![0f32; m * k];
        gemm_a_bt_with(isa, &a2, &b3, m, n, k, &mut w);
        assert_eq!(s, w);
    }
}
