//! Panel-packed f32 GEMM (C = A·B, A^T·B, A·B^T).
//!
//! Layout is row-major throughout. All three transposition variants are
//! one algorithm now: pack the (possibly strided) LHS into mr-row panels
//! and the RHS into nr-column panels ([`super::pack`]), then run a
//! k-blocked loop nest that calls the active ISA's register-tiled
//! `mr x nr` panel microkernel ([`super::simd::PanelFn`]) over contiguous
//! packed memory. A transposed operand is just a different stride pair
//! handed to the packer, so ragged edges (m, n not multiples of mr/nr)
//! are handled in exactly one place: packing zero-pads the last panel,
//! the microkernel always computes a full tile, and the driver merges
//! partial tiles through a stack scratch.
//!
//! Parallelism is over disjoint mr-row output panels via
//! `util::pool::par_rows` (packing itself is parallelized over panel
//! ranges the same way). For any one output element the k-blocks arrive
//! in ascending order and each block is a single fixed-order microkernel
//! call, so results are bit-identical for any thread count — pooled,
//! serial, and `*_with`-pinned variants agree exactly, as before.
//!
//! The pre-panel 4-row strip kernels survive as the `*_strip` serial
//! entry points: they are the perf baseline `perf_gemm`'s
//! `panel_speedup_vs_strip` series measures against, and a second oracle
//! for the property tests. The seed's `*_naive` loops remain the
//! correctness oracle.
//!
//! Packing needs workspace: the train/eval hot paths pass a
//! [`PanelBuf`] owned by the step workspace (presized at build, so the
//! warmed-up step stays allocation-free); every other caller falls back
//! to a thread-local buffer that reaches steady state after first use.

use std::cell::RefCell;

use super::pack::{self, PanelBuf};
use super::simd::{self, Isa, Kernels, MR_MAX, NR_MAX};
use crate::util::pool::{global, par_rows, SendPtr};

/// k-block for the panel driver: one block's LHS/RHS panel slices stay
/// L2-resident while the microkernel sweeps tiles; blocks beyond the
/// first accumulate into C (`acc = true`).
const KC: usize = 256;
/// k-tile of the strip baselines: the B panel rows kept hot while
/// sweeping output rows.
const KB: usize = 256;
/// n-tile of the strip baselines; KB*NB*4 = 256 KiB stays L2-resident.
const NB: usize = 256;
/// i-tile for the strip outer-product A^T·B kernel's C block.
const IB: usize = 64;
/// Below this many multiply-adds, dispatch overhead beats the pool.
const PAR_MIN_WORK: usize = 1 << 16;

/// Work grain in *panels* (each panel is mr C rows).
fn panel_grain(panels: usize) -> usize {
    let t = global().n_threads;
    panels.div_ceil(t * 4).max(1)
}

thread_local! {
    /// Fallback packing storage for callers that do not carry a
    /// workspace (preprocessing, serving, tests). Grow-only, so any
    /// steady-state caller stops allocating after its first call.
    static TLS_PANELS: RefCell<PanelBuf> = RefCell::new(PanelBuf::new());
}

// ---------------------------------------------------------------------------
// Panel driver (shared by all three orientations)
// ---------------------------------------------------------------------------

/// Run the microkernel over row panels `plo..phi` of the packed
/// operands. `c` holds exactly C rows `plo*mr .. min(phi*mr, m)` at row
/// stride `n`. Fixed (kc, q, p) order with kc outermost: every element
/// accumulates its k-blocks in ascending order no matter how panels were
/// split across threads.
#[allow(clippy::too_many_arguments)]
fn panel_rows(
    kern: &Kernels,
    pa: &[f32],
    pb: &[f32],
    m: usize,
    k: usize,
    n: usize,
    plo: usize,
    phi: usize,
    c: &mut [f32],
) {
    let (mr, nr) = (kern.mr, kern.nr);
    let np = n.div_ceil(nr);
    let mut scratch = [0f32; MR_MAX * NR_MAX];
    let mut kc0 = 0usize;
    while kc0 < k {
        let kce = (kc0 + KC).min(k);
        let kl = kce - kc0;
        let accf = kc0 > 0;
        for q in 0..np {
            let j0 = q * nr;
            let jl = nr.min(n - j0);
            let pbb = &pb[(q * k + kc0) * nr..(q * k + kce) * nr];
            for p in plo..phi {
                let i0 = p * mr;
                let il = mr.min(m - i0);
                let pab = &pa[(p * k + kc0) * mr..(p * k + kce) * mr];
                let coff = (i0 - plo * mr) * n;
                if il == mr && jl == nr {
                    (kern.panel)(kl, pab, pbb, &mut c[coff + j0..], n, accf);
                } else {
                    // partial tile: full-tile compute into scratch (the
                    // packer zero-padded the panel), merge the valid
                    // il x jl sub-rectangle
                    (kern.panel)(kl, pab, pbb, &mut scratch, nr, false);
                    for r in 0..il {
                        let crow = &mut c[coff + r * n + j0..coff + r * n + j0 + jl];
                        let srow = &scratch[r * nr..r * nr + jl];
                        if accf {
                            for (cv, &sv) in crow.iter_mut().zip(srow) {
                                *cv += sv;
                            }
                        } else {
                            crow.copy_from_slice(srow);
                        }
                    }
                }
            }
        }
        kc0 = kce;
    }
}

/// The shared panel GEMM: C[m x n] = L[m x k] @ R[k x n], where L's
/// element (i, kk) is `a[i*ars + kk*acs]` and R's element (kk, j) is
/// `b[kk*brs + j*bcs]` — each orientation wrapper supplies the stride
/// pair that expresses its transposition. Packs both operands once into
/// `buf`, then sweeps the k-blocked tile nest.
#[allow(clippy::too_many_arguments)]
fn panel_gemm(
    kern: &'static Kernels,
    a: &[f32],
    ars: usize,
    acs: usize,
    b: &[f32],
    brs: usize,
    bcs: usize,
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    buf: &mut PanelBuf,
    pooled: bool,
) {
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.fill(0.0);
        return;
    }
    let (mr, nr) = (kern.mr, kern.nr);
    let mp = m.div_ceil(mr);
    let np = n.div_ceil(nr);
    let la = mp * k * mr;
    let lb = np * k * nr;
    buf.ensure(la, lb);
    let (pa, pb) = buf.views(la, lb);
    let pooled = pooled && m * k * n >= PAR_MIN_WORK;
    if !pooled {
        pack::pack_lhs(a, ars, acs, m, k, mr, 0, mp, pa);
        pack::pack_rhs(b, brs, bcs, k, n, nr, 0, np, pb);
        panel_rows(kern, pa, pb, m, k, n, 0, mp, c);
        return;
    }
    {
        // parallel pack: disjoint panel ranges write disjoint buffer
        // ranges, and each byte's value is position-determined, so the
        // packed images are identical to a serial pack.
        let pap = SendPtr(pa.as_mut_ptr());
        par_rows(mp, panel_grain(mp), &|plo, phi| {
            // SAFETY: par_rows hands out disjoint panel ranges.
            let dst = unsafe { pap.slice(plo * k * mr, (phi - plo) * k * mr) };
            pack::pack_lhs(a, ars, acs, m, k, mr, plo, phi, dst);
        });
        let pbp = SendPtr(pb.as_mut_ptr());
        par_rows(np, panel_grain(np), &|qlo, qhi| {
            // SAFETY: disjoint panel ranges.
            let dst = unsafe { pbp.slice(qlo * k * nr, (qhi - qlo) * k * nr) };
            pack::pack_rhs(b, brs, bcs, k, n, nr, qlo, qhi, dst);
        });
    }
    let (pa, pb) = (&*pa, &*pb);
    let cp = SendPtr(c.as_mut_ptr());
    par_rows(mp, panel_grain(mp), &|plo, phi| {
        let i0 = plo * mr;
        let ie = (phi * mr).min(m);
        // SAFETY: disjoint C row ranges (panels never straddle a split).
        let rows = unsafe { cp.slice(i0 * n, (ie - i0) * n) };
        panel_rows(kern, pa, pb, m, k, n, plo, phi, rows);
    });
}

// ---------------------------------------------------------------------------
// C[m x n] = A[m x k] @ B[k x n]
// ---------------------------------------------------------------------------

fn gemm_asserts(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &[f32]) {
    assert_eq!(a.len(), m * k, "gemm: A length");
    assert_eq!(b.len(), k * n, "gemm: B length");
    assert_eq!(c.len(), m * n, "gemm: C length");
}

/// C = A·B, panel-packed + parallel (the default forward kernel).
pub fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    gemm_asserts(a, b, m, k, n, c);
    let kern = simd::kernels();
    TLS_PANELS.with(|buf| {
        panel_gemm(kern, a, k, 1, b, n, 1, m, k, n, c, &mut buf.borrow_mut(), true)
    });
}

/// C = A·B into caller-owned packing storage (the workspace hot path:
/// with `buf` presized via [`PanelBuf::reserve_gemm`], this allocates
/// nothing). Same bits as [`gemm`].
pub fn gemm_into(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    buf: &mut PanelBuf,
) {
    gemm_asserts(a, b, m, k, n, c);
    panel_gemm(simd::kernels(), a, k, 1, b, n, 1, m, k, n, c, buf, true);
}

/// C = A·B, single-threaded; bit-for-bit equal to [`gemm`].
pub fn gemm_serial(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    gemm_asserts(a, b, m, k, n, c);
    let kern = simd::kernels();
    TLS_PANELS.with(|buf| {
        panel_gemm(kern, a, k, 1, b, n, 1, m, k, n, c, &mut buf.borrow_mut(), false)
    });
}

/// C = A·B with an explicit ISA rung, single-threaded. Test/bench hook:
/// lets callers compare rungs without touching the global dispatch.
pub fn gemm_with(isa: Isa, a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    gemm_asserts(a, b, m, k, n, c);
    let kern = simd::kernels_for(isa);
    TLS_PANELS.with(|buf| {
        panel_gemm(kern, a, k, 1, b, n, 1, m, k, n, c, &mut buf.borrow_mut(), false)
    });
}

/// C = A·B through the pre-panel 4-row strip kernels, single-threaded.
/// Perf baseline for `panel_speedup_vs_strip` and a second oracle for
/// the property suite.
pub fn gemm_strip(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    gemm_asserts(a, b, m, k, n, c);
    gemm_rows(simd::kernels(), a, b, k, n, 0, m, c);
}

/// The seed's ikj loop (one row of B streamed per A value, zero-skip):
/// correctness oracle.
pub fn gemm_naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    gemm_asserts(a, b, m, k, n, c);
    for (arow, crow) in a.chunks_exact(k).zip(c.chunks_exact_mut(n)) {
        crow.fill(0.0);
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Strip kernel: compute rows `lo..hi` of C = A·B into `c` (which holds
/// exactly those rows). Fixed (kb, jb) tile order per row.
#[allow(clippy::too_many_arguments)]
fn gemm_rows(
    kern: &Kernels,
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    lo: usize,
    hi: usize,
    c: &mut [f32],
) {
    c.fill(0.0);
    let rows = hi - lo;
    let mut kb = 0;
    while kb < k {
        let ke = (kb + KB).min(k);
        let mut jb = 0;
        while jb < n {
            let je = (jb + NB).min(n);
            let mut r = 0usize;
            // 4-row strips: one B-panel read feeds four C rows.
            while r + 4 <= rows {
                let i = lo + r;
                let (c01, c23) = c[r * n..(r + 4) * n].split_at_mut(2 * n);
                let (c0, c1) = c01.split_at_mut(n);
                let (c2, c3) = c23.split_at_mut(n);
                let c0 = &mut c0[jb..je];
                let c1 = &mut c1[jb..je];
                let c2 = &mut c2[jb..je];
                let c3 = &mut c3[jb..je];
                for p in kb..ke {
                    let a0 = a[i * k + p];
                    let a1 = a[(i + 1) * k + p];
                    let a2 = a[(i + 2) * k + p];
                    let a3 = a[(i + 3) * k + p];
                    if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                        continue;
                    }
                    let br = &b[p * n + jb..p * n + je];
                    (kern.axpy4)(&[a0, a1, a2, a3], br, c0, c1, c2, c3);
                }
                r += 4;
            }
            // tail rows, one at a time
            while r < rows {
                let i = lo + r;
                let crow = &mut c[r * n + jb..r * n + je];
                for p in kb..ke {
                    let av = a[i * k + p];
                    if av == 0.0 {
                        continue;
                    }
                    let br = &b[p * n + jb..p * n + je];
                    (kern.axpy1)(av, br, crow);
                }
                r += 1;
            }
            jb = je;
        }
        kb = ke;
    }
}

// ---------------------------------------------------------------------------
// C[k x n] = A^T @ B   (A is m x k, B is m x n) — the dW = X^T·dZ kernel
// ---------------------------------------------------------------------------

fn at_b_asserts(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &[f32]) {
    assert_eq!(a.len(), m * k, "gemm_at_b: A length");
    assert_eq!(b.len(), m * n, "gemm_at_b: B length");
    assert_eq!(c.len(), k * n, "gemm_at_b: C length");
}

/// C = A^T·B, panel-packed + parallel. The packer reads A column-major
/// (stride pair (1, k)) — no explicit transpose is ever materialized.
pub fn gemm_at_b(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    at_b_asserts(a, b, m, k, n, c);
    let kern = simd::kernels();
    TLS_PANELS.with(|buf| {
        panel_gemm(kern, a, 1, k, b, n, 1, k, m, n, c, &mut buf.borrow_mut(), true)
    });
}

/// C = A^T·B into caller-owned packing storage (workspace hot path).
pub fn gemm_at_b_into(
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    c: &mut [f32],
    buf: &mut PanelBuf,
) {
    at_b_asserts(a, b, m, k, n, c);
    panel_gemm(simd::kernels(), a, 1, k, b, n, 1, k, m, n, c, buf, true);
}

/// C = A^T·B, single-threaded; bit-for-bit equal to [`gemm_at_b`].
pub fn gemm_at_b_serial(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    at_b_asserts(a, b, m, k, n, c);
    let kern = simd::kernels();
    TLS_PANELS.with(|buf| {
        panel_gemm(kern, a, 1, k, b, n, 1, k, m, n, c, &mut buf.borrow_mut(), false)
    });
}

/// C = A^T·B with an explicit ISA rung, single-threaded (test/bench hook).
pub fn gemm_at_b_with(isa: Isa, a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    at_b_asserts(a, b, m, k, n, c);
    let kern = simd::kernels_for(isa);
    TLS_PANELS.with(|buf| {
        panel_gemm(kern, a, 1, k, b, n, 1, k, m, n, c, &mut buf.borrow_mut(), false)
    });
}

/// C = A^T·B through the pre-panel strip kernels, single-threaded.
pub fn gemm_at_b_strip(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    at_b_asserts(a, b, m, k, n, c);
    at_b_rows(simd::kernels(), a, b, m, k, n, 0, k, c);
}

/// The seed's A^T·B loop (per-sample outer products, zero-skip).
pub fn gemm_at_b_naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32]) {
    at_b_asserts(a, b, m, k, n, c);
    c.fill(0.0);
    for (arow, brow) in a.chunks_exact(k).zip(b.chunks_exact(n)) {
        for (i, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Strip kernel: compute C rows `ilo..ihi` (features of A) into `c`.
/// Outer-product form preserves the zero-skip on A.
#[allow(clippy::too_many_arguments)]
fn at_b_rows(
    kern: &Kernels,
    a: &[f32],
    b: &[f32],
    m: usize,
    k: usize,
    n: usize,
    ilo: usize,
    ihi: usize,
    c: &mut [f32],
) {
    c.fill(0.0);
    let rows = ihi - ilo;
    let mut jb = 0;
    while jb < n {
        let je = (jb + NB).min(n);
        let mut ib = 0;
        while ib < rows {
            let ie = (ib + IB).min(rows);
            for t in 0..m {
                let arow = &a[t * k + ilo + ib..t * k + ilo + ie];
                let brow = &b[t * n + jb..t * n + je];
                for (r2, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let base = (ib + r2) * n;
                    let crow = &mut c[base + jb..base + je];
                    (kern.axpy1)(av, brow, crow);
                }
            }
            ib = ie;
        }
        jb = je;
    }
}

// ---------------------------------------------------------------------------
// C[m x k] = A @ B^T   (A is m x n, B is k x n) — the dX = dZ·W^T kernel
// ---------------------------------------------------------------------------

fn a_bt_asserts(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, c: &[f32]) {
    assert_eq!(a.len(), m * n, "gemm_a_bt: A length");
    assert_eq!(b.len(), k * n, "gemm_a_bt: B length");
    assert_eq!(c.len(), m * k, "gemm_a_bt: C length");
}

/// C = A·B^T, panel-packed + parallel. The packer reads B column-major
/// (stride pair (1, n)) to realize the transpose.
pub fn gemm_a_bt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, c: &mut [f32]) {
    a_bt_asserts(a, b, m, n, k, c);
    let kern = simd::kernels();
    TLS_PANELS.with(|buf| {
        panel_gemm(kern, a, n, 1, b, 1, n, m, n, k, c, &mut buf.borrow_mut(), true)
    });
}

/// C = A·B^T into caller-owned packing storage (workspace hot path).
pub fn gemm_a_bt_into(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    c: &mut [f32],
    buf: &mut PanelBuf,
) {
    a_bt_asserts(a, b, m, n, k, c);
    panel_gemm(simd::kernels(), a, n, 1, b, 1, n, m, n, k, c, buf, true);
}

/// C = A·B^T, single-threaded; bit-for-bit equal to [`gemm_a_bt`].
pub fn gemm_a_bt_serial(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, c: &mut [f32]) {
    a_bt_asserts(a, b, m, n, k, c);
    let kern = simd::kernels();
    TLS_PANELS.with(|buf| {
        panel_gemm(kern, a, n, 1, b, 1, n, m, n, k, c, &mut buf.borrow_mut(), false)
    });
}

/// C = A·B^T with an explicit ISA rung, single-threaded (test/bench hook).
pub fn gemm_a_bt_with(isa: Isa, a: &[f32], b: &[f32], m: usize, n: usize, k: usize, c: &mut [f32]) {
    a_bt_asserts(a, b, m, n, k, c);
    let kern = simd::kernels_for(isa);
    TLS_PANELS.with(|buf| {
        panel_gemm(kern, a, n, 1, b, 1, n, m, n, k, c, &mut buf.borrow_mut(), false)
    });
}

/// C = A·B^T through the pre-panel strip kernels, single-threaded.
pub fn gemm_a_bt_strip(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, c: &mut [f32]) {
    a_bt_asserts(a, b, m, n, k, c);
    a_bt_rows(simd::kernels(), a, b, n, k, 0, m, c);
}

/// The seed's A·B^T loop (single-accumulator row dots).
pub fn gemm_a_bt_naive(a: &[f32], b: &[f32], m: usize, n: usize, k: usize, c: &mut [f32]) {
    a_bt_asserts(a, b, m, n, k, c);
    for (arow, crow) in a.chunks_exact(n).zip(c.chunks_exact_mut(k)) {
        for (i, cv) in crow.iter_mut().enumerate() {
            let brow = &b[i * n..(i + 1) * n];
            let mut acc = 0f32;
            for (&av, &bv) in arow.iter().zip(brow) {
                acc += av * bv;
            }
            *cv = acc;
        }
    }
}

/// Strip kernel: compute C rows `lo..hi` (batch rows) into `c`; n is
/// tiled so the B rows being dotted stay cache-resident.
#[allow(clippy::too_many_arguments)]
fn a_bt_rows(
    kern: &Kernels,
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    lo: usize,
    hi: usize,
    c: &mut [f32],
) {
    debug_assert_eq!(c.len(), (hi - lo) * k);
    c.fill(0.0);
    let mut nb = 0;
    while nb < n {
        let ne = (nb + NB).min(n);
        for (r, crow) in c.chunks_exact_mut(k).enumerate() {
            let t = lo + r;
            let arow = &a[t * n + nb..t * n + ne];
            for (i, cv) in crow.iter_mut().enumerate() {
                let brow = &b[i * n + nb..i * n + ne];
                *cv += (kern.dot)(arow, brow);
            }
        }
        nb = ne;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand(len: usize, seed: u64, sparsity: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..len)
            .map(|_| if rng.uniform() < sparsity { 0.0 } else { rng.normal() })
            .collect()
    }

    fn close(xs: &[f32], ys: &[f32], tol: f32) {
        assert_eq!(xs.len(), ys.len());
        for (i, (x, y)) in xs.iter().zip(ys).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "[{i}] {x} vs {y}");
        }
    }

    #[test]
    fn blocked_gemm_matches_naive_across_shapes() {
        // shapes straddling the KC tile edges and non-multiples of mr/nr
        for (m, k, n, seed) in
            [(1, 1, 1, 1u64), (3, 5, 7, 2), (7, 257, 300, 3), (100, 256, 256, 4), (13, 300, 9, 5)]
        {
            let a = rand(m * k, seed, 0.4);
            let b = rand(k * n, seed + 50, 0.0);
            let mut want = vec![0f32; m * n];
            gemm_naive(&a, &b, m, k, n, &mut want);
            let mut got = vec![0f32; m * n];
            gemm(&a, &b, m, k, n, &mut got);
            close(&got, &want, 1e-4);
            let mut st = vec![0f32; m * n];
            gemm_serial(&a, &b, m, k, n, &mut st);
            assert_eq!(st, got, "pooled vs serial must be bit-identical");
            let mut sp = vec![0f32; m * n];
            gemm_strip(&a, &b, m, k, n, &mut sp);
            close(&sp, &want, 1e-4);
        }
    }

    #[test]
    fn blocked_at_b_matches_naive() {
        for (m, k, n, seed) in [(4, 6, 3, 10u64), (33, 300, 70, 11), (64, 128, 257, 12)] {
            let a = rand(m * k, seed, 0.5);
            let b = rand(m * n, seed + 50, 0.0);
            let mut want = vec![0f32; k * n];
            gemm_at_b_naive(&a, &b, m, k, n, &mut want);
            let mut got = vec![0f32; k * n];
            gemm_at_b(&a, &b, m, k, n, &mut got);
            close(&got, &want, 1e-4);
            let mut st = vec![0f32; k * n];
            gemm_at_b_serial(&a, &b, m, k, n, &mut st);
            assert_eq!(st, got);
            let mut sp = vec![0f32; k * n];
            gemm_at_b_strip(&a, &b, m, k, n, &mut sp);
            close(&sp, &want, 1e-4);
        }
    }

    #[test]
    fn blocked_a_bt_matches_naive() {
        for (m, n, k, seed) in [(5, 9, 4, 20u64), (40, 300, 33, 21), (64, 257, 128, 22)] {
            let a = rand(m * n, seed, 0.0);
            let b = rand(k * n, seed + 50, 0.0);
            let mut want = vec![0f32; m * k];
            gemm_a_bt_naive(&a, &b, m, n, k, &mut want);
            let mut got = vec![0f32; m * k];
            gemm_a_bt(&a, &b, m, n, k, &mut got);
            close(&got, &want, 1e-4);
            let mut st = vec![0f32; m * k];
            gemm_a_bt_serial(&a, &b, m, n, k, &mut st);
            assert_eq!(st, got);
            let mut sp = vec![0f32; m * k];
            gemm_a_bt_strip(&a, &b, m, n, k, &mut sp);
            close(&sp, &want, 1e-4);
        }
    }

    #[test]
    fn kernels_overwrite_stale_output() {
        // C buffers are reused across steps by the workspace; every kernel
        // must fully overwrite, never accumulate into, stale contents.
        let a = vec![1.0f32, 2.0];
        let b = vec![3.0f32, 4.0];
        let mut c = vec![99.0f32];
        gemm(&a, &b, 1, 2, 1, &mut c);
        assert_eq!(c, vec![11.0]);
        let mut c2 = vec![99.0f32, 99.0, 99.0, 99.0];
        gemm_at_b(&a, &b, 1, 2, 2, &mut c2); // A 1x2, B 1x2 -> C 2x2
        assert_eq!(c2, vec![3.0, 4.0, 6.0, 8.0]);
        let mut c3 = vec![99.0f32];
        gemm_a_bt(&a, &b, 1, 2, 1, &mut c3); // A 1x2, B 1x2 -> C 1x1
        assert_eq!(c3, vec![11.0]);
        // k == 0: an empty reduction must still clear C
        let mut c4 = vec![99.0f32; 6];
        gemm(&[], &[], 2, 0, 3, &mut c4);
        assert_eq!(c4, vec![0.0; 6]);
    }

    #[test]
    fn explicit_isa_variants_match_active_dispatch() {
        // gemm_with(active) must equal gemm_serial (same table, same
        // single-threaded path) — the hook is a pinning, not a fork.
        let isa = simd::active();
        let (m, k, n) = (7, 130, 65);
        let a = rand(m * k, 31, 0.3);
        let b = rand(k * n, 32, 0.0);
        let mut via_serial = vec![0f32; m * n];
        gemm_serial(&a, &b, m, k, n, &mut via_serial);
        let mut via_with = vec![0f32; m * n];
        gemm_with(isa, &a, &b, m, k, n, &mut via_with);
        assert_eq!(via_serial, via_with);
        let b2 = rand(m * n, 33, 0.0);
        let mut s = vec![0f32; k * n];
        gemm_at_b_serial(&a, &b2, m, k, n, &mut s);
        let mut w = vec![0f32; k * n];
        gemm_at_b_with(isa, &a, &b2, m, k, n, &mut w);
        assert_eq!(s, w);
        let a2 = rand(m * n, 34, 0.0);
        let b3 = rand(k * n, 35, 0.0);
        let mut s = vec![0f32; m * k];
        gemm_a_bt_serial(&a2, &b3, m, n, k, &mut s);
        let mut w = vec![0f32; m * k];
        gemm_a_bt_with(isa, &a2, &b3, m, n, k, &mut w);
        assert_eq!(s, w);
    }

    #[test]
    fn into_variants_match_and_reuse_buffers() {
        let (m, k, n) = (37, 129, 66);
        let a = rand(m * k, 41, 0.3);
        let b = rand(k * n, 42, 0.0);
        let mut buf = PanelBuf::new();
        let mut via_into = vec![0f32; m * n];
        gemm_into(&a, &b, m, k, n, &mut via_into, &mut buf);
        let mut via_tls = vec![0f32; m * n];
        gemm(&a, &b, m, k, n, &mut via_tls);
        assert_eq!(via_into, via_tls, "gemm_into must equal gemm bit-for-bit");
        // reuse the same (now stale-contented) buffer for the other
        // orientations — packing must fully overwrite what it needs
        let b2 = rand(m * n, 43, 0.0);
        let mut s = vec![0f32; k * n];
        gemm_at_b(&a, &b2, m, k, n, &mut s);
        let mut w = vec![0f32; k * n];
        gemm_at_b_into(&a, &b2, m, k, n, &mut w, &mut buf);
        assert_eq!(s, w);
        let a2 = rand(m * n, 44, 0.0);
        let b3 = rand(k * n, 45, 0.0);
        let mut s = vec![0f32; m * k];
        gemm_a_bt(&a2, &b3, m, n, k, &mut s);
        let mut w = vec![0f32; m * k];
        gemm_a_bt_into(&a2, &b3, m, n, k, &mut w, &mut buf);
        assert_eq!(s, w);
    }
}
