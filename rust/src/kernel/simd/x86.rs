//! x86_64 microkernels: SSE2 (baseline, always runnable) and AVX2 + FMA
//! (runtime-detected). SIMD intrinsics live only in this file and its
//! aarch64 sibling; everything `unsafe` is cordoned here behind safe
//! shims.
//!
//! Shim contract: each `pub(super)` shim is a *safe* `fn` matching the
//! [`super::Kernels`] table signature. It derives the element count from
//! the slices it was handed (so the raw-pointer inner kernel can never
//! read or write out of bounds, whatever the caller did), then calls the
//! `unsafe` inner kernel. AVX2 shims are only reachable through the AVX2
//! table, which [`super::kernels_for`] hands out strictly after runtime
//! feature detection — that is what makes executing the
//! `#[target_feature]` code sound.
//!
//! Exactness notes (see the module docs of [`super`]):
//! * `*_add` / `*_sign_accum` are bit-exact with scalar (independent
//!   lanes, same per-lane order).
//! * `axpy1` and row `r` of `axpy4` produce bit-identical results within
//!   one ISA (same vector-vs-tail boundary, same per-lane op), which is
//!   what keeps the pooled and serial blocked GEMMs equal when a row
//!   falls in a 4-strip in one split and in the tail of another.

use std::arch::x86_64::*;

// ---------------------------------------------------------------------
// SSE2 (x86_64 baseline)
// ---------------------------------------------------------------------

pub(super) fn sse2_axpy4(
    a: &[f32; 4],
    b: &[f32],
    c0: &mut [f32],
    c1: &mut [f32],
    c2: &mut [f32],
    c3: &mut [f32],
) {
    let n = b.len().min(c0.len()).min(c1.len()).min(c2.len()).min(c3.len());
    // SAFETY: SSE2 is baseline on x86_64; every offset below is < n,
    // which is within all six slices by the min above.
    unsafe {
        axpy4_sse2(
            a,
            b.as_ptr(),
            c0.as_mut_ptr(),
            c1.as_mut_ptr(),
            c2.as_mut_ptr(),
            c3.as_mut_ptr(),
            n,
        )
    }
}

unsafe fn axpy4_sse2(
    a: &[f32; 4],
    b: *const f32,
    c0: *mut f32,
    c1: *mut f32,
    c2: *mut f32,
    c3: *mut f32,
    n: usize,
) {
    let va0 = _mm_set1_ps(a[0]);
    let va1 = _mm_set1_ps(a[1]);
    let va2 = _mm_set1_ps(a[2]);
    let va3 = _mm_set1_ps(a[3]);
    let mut j = 0usize;
    while j + 4 <= n {
        let vb = _mm_loadu_ps(b.add(j));
        _mm_storeu_ps(c0.add(j), _mm_add_ps(_mm_loadu_ps(c0.add(j)), _mm_mul_ps(va0, vb)));
        _mm_storeu_ps(c1.add(j), _mm_add_ps(_mm_loadu_ps(c1.add(j)), _mm_mul_ps(va1, vb)));
        _mm_storeu_ps(c2.add(j), _mm_add_ps(_mm_loadu_ps(c2.add(j)), _mm_mul_ps(va2, vb)));
        _mm_storeu_ps(c3.add(j), _mm_add_ps(_mm_loadu_ps(c3.add(j)), _mm_mul_ps(va3, vb)));
        j += 4;
    }
    while j < n {
        let bv = *b.add(j);
        *c0.add(j) += a[0] * bv;
        *c1.add(j) += a[1] * bv;
        *c2.add(j) += a[2] * bv;
        *c3.add(j) += a[3] * bv;
        j += 1;
    }
}

pub(super) fn sse2_axpy1(a: f32, b: &[f32], c: &mut [f32]) {
    let n = b.len().min(c.len());
    // SAFETY: SSE2 baseline; offsets < n are in bounds of both slices.
    unsafe { axpy1_sse2(a, b.as_ptr(), c.as_mut_ptr(), n) }
}

unsafe fn axpy1_sse2(a: f32, b: *const f32, c: *mut f32, n: usize) {
    let va = _mm_set1_ps(a);
    let mut j = 0usize;
    while j + 8 <= n {
        let m0 = _mm_mul_ps(va, _mm_loadu_ps(b.add(j)));
        _mm_storeu_ps(c.add(j), _mm_add_ps(_mm_loadu_ps(c.add(j)), m0));
        let m1 = _mm_mul_ps(va, _mm_loadu_ps(b.add(j + 4)));
        _mm_storeu_ps(c.add(j + 4), _mm_add_ps(_mm_loadu_ps(c.add(j + 4)), m1));
        j += 8;
    }
    while j + 4 <= n {
        let m0 = _mm_mul_ps(va, _mm_loadu_ps(b.add(j)));
        _mm_storeu_ps(c.add(j), _mm_add_ps(_mm_loadu_ps(c.add(j)), m0));
        j += 4;
    }
    while j < n {
        *c.add(j) += a * *b.add(j);
        j += 1;
    }
}

pub(super) fn sse2_dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    // SAFETY: SSE2 baseline; reads stay below n.
    unsafe { dot_sse2(a.as_ptr(), b.as_ptr(), n) }
}

unsafe fn dot_sse2(a: *const f32, b: *const f32, n: usize) -> f32 {
    let mut acc0 = _mm_setzero_ps();
    let mut acc1 = _mm_setzero_ps();
    let mut j = 0usize;
    while j + 8 <= n {
        acc0 = _mm_add_ps(acc0, _mm_mul_ps(_mm_loadu_ps(a.add(j)), _mm_loadu_ps(b.add(j))));
        let m1 = _mm_mul_ps(_mm_loadu_ps(a.add(j + 4)), _mm_loadu_ps(b.add(j + 4)));
        acc1 = _mm_add_ps(acc1, m1);
        j += 8;
    }
    if j + 4 <= n {
        acc0 = _mm_add_ps(acc0, _mm_mul_ps(_mm_loadu_ps(a.add(j)), _mm_loadu_ps(b.add(j))));
        j += 4;
    }
    let mut s = hsum128(_mm_add_ps(acc0, acc1));
    while j < n {
        s += *a.add(j) * *b.add(j);
        j += 1;
    }
    s
}

pub(super) fn sse2_add(dst: &mut [f32], src: &[f32]) {
    let n = dst.len().min(src.len());
    // SAFETY: SSE2 baseline; offsets < n are within both slices.
    unsafe { add_sse2(dst.as_mut_ptr(), src.as_ptr(), n) }
}

unsafe fn add_sse2(dst: *mut f32, src: *const f32, n: usize) {
    let mut j = 0usize;
    while j + 4 <= n {
        _mm_storeu_ps(dst.add(j), _mm_add_ps(_mm_loadu_ps(dst.add(j)), _mm_loadu_ps(src.add(j))));
        j += 4;
    }
    while j < n {
        *dst.add(j) += *src.add(j);
        j += 1;
    }
}

pub(super) fn sse2_sign_accum(col: &[u64], xt: &[f32], b: usize, c0: usize, sel: &mut [f32]) {
    if let Some(r) = super::highest_set_row(col) {
        assert!(r * b + c0 + sel.len() <= xt.len(), "sign_accum: stripe out of bounds");
    }
    // SAFETY: the assert above bounds every stripe the inner kernel
    // reads (bits only reach rows <= highest_set_row); sel writes stay
    // below sel.len(). SSE2 baseline.
    unsafe { sign_accum_sse2(col, xt.as_ptr(), b, c0, sel) }
}

unsafe fn sign_accum_sse2(col: &[u64], xt: *const f32, b: usize, c0: usize, sel: &mut [f32]) {
    let len = sel.len();
    let sp = sel.as_mut_ptr();
    for (wi, &word) in col.iter().enumerate() {
        if word == 0 {
            continue;
        }
        let base = wi * 64;
        let mut m = word;
        while m != 0 {
            let t = m.trailing_zeros() as usize;
            let xp = xt.add((base + t) * b + c0);
            let mut c = 0usize;
            while c + 4 <= len {
                _mm_storeu_ps(
                    sp.add(c),
                    _mm_add_ps(_mm_loadu_ps(sp.add(c)), _mm_loadu_ps(xp.add(c))),
                );
                c += 4;
            }
            while c < len {
                *sp.add(c) += *xp.add(c);
                c += 1;
            }
            m &= m - 1;
        }
    }
}

pub(super) fn sse2_panel(k: usize, pa: &[f32], pb: &[f32], c: &mut [f32], ldc: usize, acc: bool) {
    const MR: usize = 4;
    const NR: usize = 8;
    assert!(pa.len() >= k * MR, "sse2_panel: packed LHS too short");
    assert!(pb.len() >= k * NR, "sse2_panel: packed RHS too short");
    assert!(ldc >= NR && c.len() >= (MR - 1) * ldc + NR, "sse2_panel: C tile out of range");
    // SAFETY: SSE2 baseline; the asserts bound every pa/pb read at
    // k*MR / k*NR and every C access at row r's [r*ldc, r*ldc+NR).
    unsafe { panel_sse2(k, pa.as_ptr(), pb.as_ptr(), c.as_mut_ptr(), ldc, acc) }
}

unsafe fn panel_sse2(k: usize, pa: *const f32, pb: *const f32, c: *mut f32, ldc: usize, acc: bool) {
    // 4x8 tile in eight xmm accumulators: acc{r}{h} covers row r,
    // columns h*4 .. h*4+4.
    let mut a00 = _mm_setzero_ps();
    let mut a01 = _mm_setzero_ps();
    let mut a10 = _mm_setzero_ps();
    let mut a11 = _mm_setzero_ps();
    let mut a20 = _mm_setzero_ps();
    let mut a21 = _mm_setzero_ps();
    let mut a30 = _mm_setzero_ps();
    let mut a31 = _mm_setzero_ps();
    for kk in 0..k {
        let ap = pa.add(kk * 4);
        let bp = pb.add(kk * 8);
        let b0 = _mm_loadu_ps(bp);
        let b1 = _mm_loadu_ps(bp.add(4));
        let v0 = _mm_set1_ps(*ap);
        a00 = _mm_add_ps(a00, _mm_mul_ps(v0, b0));
        a01 = _mm_add_ps(a01, _mm_mul_ps(v0, b1));
        let v1 = _mm_set1_ps(*ap.add(1));
        a10 = _mm_add_ps(a10, _mm_mul_ps(v1, b0));
        a11 = _mm_add_ps(a11, _mm_mul_ps(v1, b1));
        let v2 = _mm_set1_ps(*ap.add(2));
        a20 = _mm_add_ps(a20, _mm_mul_ps(v2, b0));
        a21 = _mm_add_ps(a21, _mm_mul_ps(v2, b1));
        let v3 = _mm_set1_ps(*ap.add(3));
        a30 = _mm_add_ps(a30, _mm_mul_ps(v3, b0));
        a31 = _mm_add_ps(a31, _mm_mul_ps(v3, b1));
    }
    let rows = [[a00, a01], [a10, a11], [a20, a21], [a30, a31]];
    for (r, half) in rows.iter().enumerate() {
        let cp = c.add(r * ldc);
        if acc {
            _mm_storeu_ps(cp, _mm_add_ps(_mm_loadu_ps(cp), half[0]));
            _mm_storeu_ps(cp.add(4), _mm_add_ps(_mm_loadu_ps(cp.add(4)), half[1]));
        } else {
            _mm_storeu_ps(cp, half[0]);
            _mm_storeu_ps(cp.add(4), half[1]);
        }
    }
}

pub(super) fn sse2_sign_dot(col: &[u64], x: &[f32], _total: f32) -> f32 {
    assert!(col.len() * 64 >= x.len(), "sign_dot: packed column too short");
    // SAFETY: reads of x stay below x.len(); word reads stay below
    // col.len() by the assert. SSE2 baseline.
    unsafe { sign_dot_sse2(col, x.as_ptr(), x.len()) }
}

unsafe fn sign_dot_sse2(col: &[u64], x: *const f32, k: usize) -> f32 {
    let lane = _mm_setr_epi32(1, 2, 4, 8);
    let signbit = _mm_set1_epi32(i32::MIN);
    let mut acc0 = _mm_setzero_ps();
    let mut acc1 = _mm_setzero_ps();
    let mut r = 0usize;
    while r + 8 <= k {
        let b0 = _mm_set1_epi32(((*col.get_unchecked(r >> 6) >> (r & 63)) & 0xf) as i32);
        let b1 =
            _mm_set1_epi32(((*col.get_unchecked((r + 4) >> 6) >> ((r + 4) & 63)) & 0xf) as i32);
        // lanes whose weight bit is 0 (weight -1) get their sign flipped
        let f0 = _mm_castsi128_ps(_mm_andnot_si128(
            _mm_cmpeq_epi32(_mm_and_si128(b0, lane), lane),
            signbit,
        ));
        let f1 = _mm_castsi128_ps(_mm_andnot_si128(
            _mm_cmpeq_epi32(_mm_and_si128(b1, lane), lane),
            signbit,
        ));
        acc0 = _mm_add_ps(acc0, _mm_xor_ps(_mm_loadu_ps(x.add(r)), f0));
        acc1 = _mm_add_ps(acc1, _mm_xor_ps(_mm_loadu_ps(x.add(r + 4)), f1));
        r += 8;
    }
    if r + 4 <= k {
        let b0 = _mm_set1_epi32(((*col.get_unchecked(r >> 6) >> (r & 63)) & 0xf) as i32);
        let f0 = _mm_castsi128_ps(_mm_andnot_si128(
            _mm_cmpeq_epi32(_mm_and_si128(b0, lane), lane),
            signbit,
        ));
        acc0 = _mm_add_ps(acc0, _mm_xor_ps(_mm_loadu_ps(x.add(r)), f0));
        r += 4;
    }
    let mut s = hsum128(_mm_add_ps(acc0, acc1));
    while r < k {
        let bit = (*col.get_unchecked(r >> 6) >> (r & 63)) & 1;
        let v = *x.add(r);
        s += if bit == 1 { v } else { -v };
        r += 1;
    }
    s
}

pub(super) fn sse2_sign_xnor_dot(a: &[u64], b: &[u64]) -> u32 {
    // SSE2 has no vector popcount (PSHUFB arrives with SSSE3, POPCNT
    // with SSE4.2), so this rung is a 4-word-unrolled scalar loop:
    // `count_ones` lowers to the baseline-x86_64 SWAR sequence, and the
    // unroll gives the four chains independent registers. Integer sums
    // are associative, so it is bit-exact with every other rung.
    let n = a.len().min(b.len());
    let (a, b) = (&a[..n], &b[..n]);
    let mut s0 = 0u32;
    let mut s1 = 0u32;
    let mut s2 = 0u32;
    let mut s3 = 0u32;
    let mut i = 0usize;
    while i + 4 <= n {
        s0 += (a[i] ^ b[i]).count_ones();
        s1 += (a[i + 1] ^ b[i + 1]).count_ones();
        s2 += (a[i + 2] ^ b[i + 2]).count_ones();
        s3 += (a[i + 3] ^ b[i + 3]).count_ones();
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while i < n {
        s += (a[i] ^ b[i]).count_ones();
        i += 1;
    }
    s
}

#[inline]
unsafe fn hsum128(v: __m128) -> f32 {
    let s = _mm_add_ps(v, _mm_movehl_ps(v, v));
    let s = _mm_add_ss(s, _mm_shuffle_ps::<1>(s, s));
    _mm_cvtss_f32(s)
}

// ---------------------------------------------------------------------
// AVX2 + FMA (runtime-detected)
// ---------------------------------------------------------------------

pub(super) fn avx2_axpy4(
    a: &[f32; 4],
    b: &[f32],
    c0: &mut [f32],
    c1: &mut [f32],
    c2: &mut [f32],
    c3: &mut [f32],
) {
    let n = b.len().min(c0.len()).min(c1.len()).min(c2.len()).min(c3.len());
    // SAFETY: offsets < n are within all six slices; this shim is only
    // reachable through the AVX2 table, handed out after runtime
    // detection of avx2+fma.
    unsafe {
        axpy4_avx2(
            a,
            b.as_ptr(),
            c0.as_mut_ptr(),
            c1.as_mut_ptr(),
            c2.as_mut_ptr(),
            c3.as_mut_ptr(),
            n,
        )
    }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn axpy4_avx2(
    a: &[f32; 4],
    b: *const f32,
    c0: *mut f32,
    c1: *mut f32,
    c2: *mut f32,
    c3: *mut f32,
    n: usize,
) {
    let va0 = _mm256_set1_ps(a[0]);
    let va1 = _mm256_set1_ps(a[1]);
    let va2 = _mm256_set1_ps(a[2]);
    let va3 = _mm256_set1_ps(a[3]);
    let mut j = 0usize;
    while j + 8 <= n {
        let vb = _mm256_loadu_ps(b.add(j));
        _mm256_storeu_ps(c0.add(j), _mm256_fmadd_ps(va0, vb, _mm256_loadu_ps(c0.add(j))));
        _mm256_storeu_ps(c1.add(j), _mm256_fmadd_ps(va1, vb, _mm256_loadu_ps(c1.add(j))));
        _mm256_storeu_ps(c2.add(j), _mm256_fmadd_ps(va2, vb, _mm256_loadu_ps(c2.add(j))));
        _mm256_storeu_ps(c3.add(j), _mm256_fmadd_ps(va3, vb, _mm256_loadu_ps(c3.add(j))));
        j += 8;
    }
    while j < n {
        let bv = *b.add(j);
        *c0.add(j) += a[0] * bv;
        *c1.add(j) += a[1] * bv;
        *c2.add(j) += a[2] * bv;
        *c3.add(j) += a[3] * bv;
        j += 1;
    }
}

pub(super) fn avx2_axpy1(a: f32, b: &[f32], c: &mut [f32]) {
    let n = b.len().min(c.len());
    // SAFETY: offsets < n; AVX2 table gating as in avx2_axpy4.
    unsafe { axpy1_avx2(a, b.as_ptr(), c.as_mut_ptr(), n) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn axpy1_avx2(a: f32, b: *const f32, c: *mut f32, n: usize) {
    let va = _mm256_set1_ps(a);
    let mut j = 0usize;
    while j + 16 <= n {
        let v0 = _mm256_fmadd_ps(va, _mm256_loadu_ps(b.add(j)), _mm256_loadu_ps(c.add(j)));
        _mm256_storeu_ps(c.add(j), v0);
        let j8 = j + 8;
        let v1 = _mm256_fmadd_ps(va, _mm256_loadu_ps(b.add(j8)), _mm256_loadu_ps(c.add(j8)));
        _mm256_storeu_ps(c.add(j8), v1);
        j += 16;
    }
    while j + 8 <= n {
        let v0 = _mm256_fmadd_ps(va, _mm256_loadu_ps(b.add(j)), _mm256_loadu_ps(c.add(j)));
        _mm256_storeu_ps(c.add(j), v0);
        j += 8;
    }
    while j < n {
        *c.add(j) += a * *b.add(j);
        j += 1;
    }
}

pub(super) fn avx2_dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    // SAFETY: reads stay below n; AVX2 table gating as in avx2_axpy4.
    unsafe { dot_avx2(a.as_ptr(), b.as_ptr(), n) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn dot_avx2(a: *const f32, b: *const f32, n: usize) -> f32 {
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    let mut j = 0usize;
    while j + 32 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(a.add(j)), _mm256_loadu_ps(b.add(j)), acc0);
        acc1 = _mm256_fmadd_ps(_mm256_loadu_ps(a.add(j + 8)), _mm256_loadu_ps(b.add(j + 8)), acc1);
        acc2 =
            _mm256_fmadd_ps(_mm256_loadu_ps(a.add(j + 16)), _mm256_loadu_ps(b.add(j + 16)), acc2);
        acc3 =
            _mm256_fmadd_ps(_mm256_loadu_ps(a.add(j + 24)), _mm256_loadu_ps(b.add(j + 24)), acc3);
        j += 32;
    }
    while j + 8 <= n {
        acc0 = _mm256_fmadd_ps(_mm256_loadu_ps(a.add(j)), _mm256_loadu_ps(b.add(j)), acc0);
        j += 8;
    }
    let mut s = hsum256(_mm256_add_ps(_mm256_add_ps(acc0, acc1), _mm256_add_ps(acc2, acc3)));
    while j < n {
        s += *a.add(j) * *b.add(j);
        j += 1;
    }
    s
}

pub(super) fn avx2_add(dst: &mut [f32], src: &[f32]) {
    let n = dst.len().min(src.len());
    // SAFETY: offsets < n; AVX2 table gating as in avx2_axpy4.
    unsafe { add_avx2(dst.as_mut_ptr(), src.as_ptr(), n) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn add_avx2(dst: *mut f32, src: *const f32, n: usize) {
    let mut j = 0usize;
    while j + 8 <= n {
        let v = _mm256_add_ps(_mm256_loadu_ps(dst.add(j)), _mm256_loadu_ps(src.add(j)));
        _mm256_storeu_ps(dst.add(j), v);
        j += 8;
    }
    while j < n {
        *dst.add(j) += *src.add(j);
        j += 1;
    }
}

pub(super) fn avx2_sign_accum(col: &[u64], xt: &[f32], b: usize, c0: usize, sel: &mut [f32]) {
    if let Some(r) = super::highest_set_row(col) {
        assert!(r * b + c0 + sel.len() <= xt.len(), "sign_accum: stripe out of bounds");
    }
    // SAFETY: the assert bounds every stripe read; sel writes stay below
    // sel.len(); AVX2 table gating as in avx2_axpy4.
    unsafe { sign_accum_avx2(col, xt.as_ptr(), b, c0, sel) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn sign_accum_avx2(col: &[u64], xt: *const f32, b: usize, c0: usize, sel: &mut [f32]) {
    let len = sel.len();
    let sp = sel.as_mut_ptr();
    if len == 64 {
        // the steady-state chunk: the whole 64-wide accumulator strip
        // lives in eight ymm registers across every bit of the column.
        let mut a0 = _mm256_loadu_ps(sp);
        let mut a1 = _mm256_loadu_ps(sp.add(8));
        let mut a2 = _mm256_loadu_ps(sp.add(16));
        let mut a3 = _mm256_loadu_ps(sp.add(24));
        let mut a4 = _mm256_loadu_ps(sp.add(32));
        let mut a5 = _mm256_loadu_ps(sp.add(40));
        let mut a6 = _mm256_loadu_ps(sp.add(48));
        let mut a7 = _mm256_loadu_ps(sp.add(56));
        for (wi, &word) in col.iter().enumerate() {
            if word == 0 {
                continue;
            }
            let base = wi * 64;
            let mut m = word;
            while m != 0 {
                let t = m.trailing_zeros() as usize;
                let xp = xt.add((base + t) * b + c0);
                a0 = _mm256_add_ps(a0, _mm256_loadu_ps(xp));
                a1 = _mm256_add_ps(a1, _mm256_loadu_ps(xp.add(8)));
                a2 = _mm256_add_ps(a2, _mm256_loadu_ps(xp.add(16)));
                a3 = _mm256_add_ps(a3, _mm256_loadu_ps(xp.add(24)));
                a4 = _mm256_add_ps(a4, _mm256_loadu_ps(xp.add(32)));
                a5 = _mm256_add_ps(a5, _mm256_loadu_ps(xp.add(40)));
                a6 = _mm256_add_ps(a6, _mm256_loadu_ps(xp.add(48)));
                a7 = _mm256_add_ps(a7, _mm256_loadu_ps(xp.add(56)));
                m &= m - 1;
            }
        }
        _mm256_storeu_ps(sp, a0);
        _mm256_storeu_ps(sp.add(8), a1);
        _mm256_storeu_ps(sp.add(16), a2);
        _mm256_storeu_ps(sp.add(24), a3);
        _mm256_storeu_ps(sp.add(32), a4);
        _mm256_storeu_ps(sp.add(40), a5);
        _mm256_storeu_ps(sp.add(48), a6);
        _mm256_storeu_ps(sp.add(56), a7);
    } else {
        // ragged batch tail: per-bit 8-lane adds
        for (wi, &word) in col.iter().enumerate() {
            if word == 0 {
                continue;
            }
            let base = wi * 64;
            let mut m = word;
            while m != 0 {
                let t = m.trailing_zeros() as usize;
                let xp = xt.add((base + t) * b + c0);
                let mut c = 0usize;
                while c + 8 <= len {
                    _mm256_storeu_ps(
                        sp.add(c),
                        _mm256_add_ps(_mm256_loadu_ps(sp.add(c)), _mm256_loadu_ps(xp.add(c))),
                    );
                    c += 8;
                }
                while c < len {
                    *sp.add(c) += *xp.add(c);
                    c += 1;
                }
                m &= m - 1;
            }
        }
    }
}

pub(super) fn avx2_panel(k: usize, pa: &[f32], pb: &[f32], c: &mut [f32], ldc: usize, acc: bool) {
    const MR: usize = 4;
    const NR: usize = 16;
    assert!(pa.len() >= k * MR, "avx2_panel: packed LHS too short");
    assert!(pb.len() >= k * NR, "avx2_panel: packed RHS too short");
    assert!(ldc >= NR && c.len() >= (MR - 1) * ldc + NR, "avx2_panel: C tile out of range");
    // SAFETY: the asserts bound every pa/pb read and every C access;
    // AVX2 table gating as in avx2_axpy4.
    unsafe { panel_avx2(k, pa.as_ptr(), pb.as_ptr(), c.as_mut_ptr(), ldc, acc) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn panel_avx2(k: usize, pa: *const f32, pb: *const f32, c: *mut f32, ldc: usize, acc: bool) {
    // 4x16 tile in eight ymm accumulators: acc{r}{h} covers row r,
    // columns h*8 .. h*8+8. FMA throughput-bound: two fused ops per
    // broadcast A value.
    let mut a00 = _mm256_setzero_ps();
    let mut a01 = _mm256_setzero_ps();
    let mut a10 = _mm256_setzero_ps();
    let mut a11 = _mm256_setzero_ps();
    let mut a20 = _mm256_setzero_ps();
    let mut a21 = _mm256_setzero_ps();
    let mut a30 = _mm256_setzero_ps();
    let mut a31 = _mm256_setzero_ps();
    for kk in 0..k {
        let ap = pa.add(kk * 4);
        let bp = pb.add(kk * 16);
        let b0 = _mm256_loadu_ps(bp);
        let b1 = _mm256_loadu_ps(bp.add(8));
        let v0 = _mm256_broadcast_ss(&*ap);
        a00 = _mm256_fmadd_ps(v0, b0, a00);
        a01 = _mm256_fmadd_ps(v0, b1, a01);
        let v1 = _mm256_broadcast_ss(&*ap.add(1));
        a10 = _mm256_fmadd_ps(v1, b0, a10);
        a11 = _mm256_fmadd_ps(v1, b1, a11);
        let v2 = _mm256_broadcast_ss(&*ap.add(2));
        a20 = _mm256_fmadd_ps(v2, b0, a20);
        a21 = _mm256_fmadd_ps(v2, b1, a21);
        let v3 = _mm256_broadcast_ss(&*ap.add(3));
        a30 = _mm256_fmadd_ps(v3, b0, a30);
        a31 = _mm256_fmadd_ps(v3, b1, a31);
    }
    let rows = [[a00, a01], [a10, a11], [a20, a21], [a30, a31]];
    for (r, half) in rows.iter().enumerate() {
        let cp = c.add(r * ldc);
        if acc {
            _mm256_storeu_ps(cp, _mm256_add_ps(_mm256_loadu_ps(cp), half[0]));
            _mm256_storeu_ps(cp.add(8), _mm256_add_ps(_mm256_loadu_ps(cp.add(8)), half[1]));
        } else {
            _mm256_storeu_ps(cp, half[0]);
            _mm256_storeu_ps(cp.add(8), half[1]);
        }
    }
}

pub(super) fn avx2_sign_dot(col: &[u64], x: &[f32], _total: f32) -> f32 {
    assert!(col.len() * 64 >= x.len(), "sign_dot: packed column too short");
    // SAFETY: reads of x stay below x.len(); word reads stay below
    // col.len() by the assert; AVX2 table gating as in avx2_axpy4.
    unsafe { sign_dot_avx2(col, x.as_ptr(), x.len()) }
}

#[target_feature(enable = "avx2,fma")]
unsafe fn sign_dot_avx2(col: &[u64], x: *const f32, k: usize) -> f32 {
    let lane = _mm256_setr_epi32(1, 2, 4, 8, 16, 32, 64, 128);
    let signbit = _mm256_set1_epi32(i32::MIN);
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut r = 0usize;
    while r + 16 <= k {
        let b0 = _mm256_set1_epi32(((*col.get_unchecked(r >> 6) >> (r & 63)) & 0xff) as i32);
        let b1 = _mm256_set1_epi32(
            ((*col.get_unchecked((r + 8) >> 6) >> ((r + 8) & 63)) & 0xff) as i32,
        );
        // weight bit 0 (-1) flips the lane's sign via XOR with 0x8000_0000
        let f0 = _mm256_castsi256_ps(_mm256_andnot_si256(
            _mm256_cmpeq_epi32(_mm256_and_si256(b0, lane), lane),
            signbit,
        ));
        let f1 = _mm256_castsi256_ps(_mm256_andnot_si256(
            _mm256_cmpeq_epi32(_mm256_and_si256(b1, lane), lane),
            signbit,
        ));
        acc0 = _mm256_add_ps(acc0, _mm256_xor_ps(_mm256_loadu_ps(x.add(r)), f0));
        acc1 = _mm256_add_ps(acc1, _mm256_xor_ps(_mm256_loadu_ps(x.add(r + 8)), f1));
        r += 16;
    }
    if r + 8 <= k {
        let b0 = _mm256_set1_epi32(((*col.get_unchecked(r >> 6) >> (r & 63)) & 0xff) as i32);
        let f0 = _mm256_castsi256_ps(_mm256_andnot_si256(
            _mm256_cmpeq_epi32(_mm256_and_si256(b0, lane), lane),
            signbit,
        ));
        acc0 = _mm256_add_ps(acc0, _mm256_xor_ps(_mm256_loadu_ps(x.add(r)), f0));
        r += 8;
    }
    let mut s = hsum256(_mm256_add_ps(acc0, acc1));
    while r < k {
        let bit = (*col.get_unchecked(r >> 6) >> (r & 63)) & 1;
        let v = *x.add(r);
        s += if bit == 1 { v } else { -v };
        r += 1;
    }
    s
}

pub(super) fn avx2_sign_xnor_dot(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len().min(b.len());
    // SAFETY: reads stay below n in both slices; this shim is only
    // reachable through the AVX2 table, which runtime detection hands
    // out strictly after confirming avx2+fma+popcnt.
    unsafe { sign_xnor_dot_avx2(a.as_ptr(), b.as_ptr(), n) }
}

#[target_feature(enable = "avx2,popcnt")]
unsafe fn sign_xnor_dot_avx2(a: *const u64, b: *const u64, n: usize) -> u32 {
    // Nibble-LUT popcount: per 4-word block, XOR the operands, split
    // each byte into nibbles, look both up in a replicated 16-entry
    // table via vpshufb, and horizontally fold the byte counts into
    // four u64 lanes with vpsadbw (so the epi8 sums can never
    // saturate). Exact for any input — every step counts bits, no
    // arithmetic approximation — so the rung stays bit-identical to
    // scalar.
    let lut = _mm256_setr_epi8(
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
        0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
    );
    let low = _mm256_set1_epi8(0x0f);
    let zero = _mm256_setzero_si256();
    let mut acc = _mm256_setzero_si256();
    let mut i = 0usize;
    while i + 4 <= n {
        let va = _mm256_loadu_si256(a.add(i) as *const __m256i);
        let vb = _mm256_loadu_si256(b.add(i) as *const __m256i);
        let x = _mm256_xor_si256(va, vb);
        let lo = _mm256_shuffle_epi8(lut, _mm256_and_si256(x, low));
        let hi = _mm256_shuffle_epi8(lut, _mm256_and_si256(_mm256_srli_epi16::<4>(x), low));
        let cnt = _mm256_add_epi8(lo, hi);
        acc = _mm256_add_epi64(acc, _mm256_sad_epu8(cnt, zero));
        i += 4;
    }
    let mut s = (_mm256_extract_epi64::<0>(acc)
        + _mm256_extract_epi64::<1>(acc)
        + _mm256_extract_epi64::<2>(acc)
        + _mm256_extract_epi64::<3>(acc)) as u64;
    while i < n {
        s += _popcnt64((*a.add(i) ^ *b.add(i)) as i64) as u64;
        i += 1;
    }
    s as u32
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum256(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps::<1>(v);
    let s = _mm_add_ps(lo, hi);
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_shuffle_ps::<1>(s, s));
    _mm_cvtss_f32(s)
}
