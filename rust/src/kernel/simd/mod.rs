//! Runtime-dispatched SIMD microkernels for the hot inner loops.
//!
//! The panel GEMM trio (`kernel/gemm.rs`) and the packed sign-GEMM
//! (`binary/packed.rs`) keep their tiling, threading and exactness
//! structure, but their innermost loops go through a [`Kernels`] table of
//! function pointers selected once per process:
//!
//! * **avx2** — 8-lane AVX2 + FMA microkernels (a 4x16 register-tiled
//!   panel kernel holding C in eight ymm registers), plus the bit-trick
//!   single sign-dot (each 64-bit weight word drives sign-flips of
//!   activation lanes via XOR with a mask expanded from the bits).
//! * **sse2** — 4-lane baseline-x86_64 microkernels (always available on
//!   `x86_64`; the rung the dispatcher lands on when AVX2 is absent).
//!   Its panel kernel is 4x8 over eight xmm accumulators.
//! * **neon** — 4-lane aarch64 NEON microkernels (baseline on every
//!   aarch64 target, so detection always lands here on ARM). 4x8 panel
//!   kernel over eight q-register accumulators.
//! * **scalar** — portable Rust, byte-for-byte the strip kernels that
//!   shipped before this layer existed plus a portable 4x8 panel kernel.
//!   The correctness oracle for everything above, and the only rung on
//!   targets that are neither x86_64 nor aarch64.
//!
//! Selection happens on first use:
//! `BCRUN_SIMD={auto,avx2,sse2,neon,scalar}` when set (validated like
//! `BCRUN_THREADS` — a typo or an ISA the host cannot run fails loudly,
//! and `bcrun` checks it up front), else the best rung feature detection
//! reports. [`set_active`] re-points the table at runtime — the hook
//! `perf_gemm`'s dispatch-ladder series use; tests instead go through the
//! side-door [`kernels_for`] so they never mutate process-global state.
//!
//! ## Safety boundary
//!
//! Every `unsafe` block of the SIMD layer lives in this directory
//! (`x86.rs` / `aarch64.rs` for the ISA-specific intrinsics). The table
//! entries are safe `fn`s: each shim validates slice lengths itself (so
//! its `unsafe` contract never depends on a distant caller) and an AVX2
//! shim is only reachable through a table that runtime detection
//! approved, so the `#[target_feature]` call inside it cannot fault. See
//! DESIGN.md ("SIMD dispatch") for how to add an ISA.
//!
//! ## Exactness contract (pinned by `tests/simd_kernels.rs`)
//!
//! * `sign_accum` / `add` (the batched packed forward/backward): **bit
//!   exact** across every ISA — lanes map one-to-one onto batch columns,
//!   so the per-column f32 reduction order is identical by construction.
//! * `axpy4` / `axpy1` / `dot` (the f32 GEMM trio) and `sign_dot` (the
//!   batch-1 packed path): same math, different association (FMA and wide
//!   accumulators) — equal to scalar within a 1e-5-scale bound.
//! * `sign_xnor_dot` (the BNN inference engine, `binary/bnn.rs`): **bit
//!   exact** across every ISA by definition — it returns an integer
//!   popcount of `a XOR b`, and integer addition is associative, so any
//!   vectorization/accumulation order produces the same number.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::util::pool::env_setting;

#[cfg(target_arch = "x86_64")]
mod x86;

#[cfg(target_arch = "aarch64")]
mod aarch64;

/// The instruction-set rungs the dispatcher can select.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Isa {
    /// Portable Rust (the pre-SIMD kernels, unchanged). Always supported.
    Scalar,
    /// 4-lane SSE2 (baseline on every `x86_64` target).
    Sse2,
    /// 8-lane AVX2 + FMA (runtime-detected).
    Avx2,
    /// 4-lane NEON (baseline on every `aarch64` target).
    Neon,
}

impl Isa {
    /// The `BCRUN_SIMD` spelling of this rung.
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Sse2 => "sse2",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    /// Can this host execute the rung's kernels?
    pub fn supported(self) -> bool {
        match self {
            Isa::Scalar => true,
            Isa::Sse2 => cfg!(target_arch = "x86_64"),
            Isa::Avx2 => detect() == Isa::Avx2,
            Isa::Neon => cfg!(target_arch = "aarch64"),
        }
    }
}

/// Every rung, best first (iterate + filter by [`Isa::supported`]).
/// Avx2/Sse2 and Neon are mutually exclusive per target, so "best first"
/// is well-defined within any one host's supported subset.
pub const ALL_ISAS: [Isa; 4] = [Isa::Avx2, Isa::Sse2, Isa::Neon, Isa::Scalar];

/// `c_r[j] += a[r] * b[j]` for four output rows sharing one B panel.
pub type Axpy4Fn = fn(&[f32; 4], &[f32], &mut [f32], &mut [f32], &mut [f32], &mut [f32]);
/// `c[j] += a * b[j]`.
pub type Axpy1Fn = fn(f32, &[f32], &mut [f32]);
/// `Σ_i a[i] * b[i]`, fixed per-ISA reduction order.
pub type DotFn = fn(&[f32], &[f32]) -> f32;
/// `dst[i] += src[i]` over `dst.len()` lanes.
pub type AddFn = fn(&mut [f32], &[f32]);
/// Batched selected-sum: for every set bit (word-ascending, bit-ascending)
/// at row `r` of the packed column, `sel[c] += xt[r * b + c0 + c]`.
pub type SignAccumFn = fn(&[u64], &[f32], usize, usize, &mut [f32]);
/// Batch-1 signed dot `Σ_i sign_i * x[i]` for one packed column; `total`
/// is `Σ_i x[i]` (the scalar rung computes `2 * selected - total`, the
/// SIMD rungs sign-flip lanes directly and ignore it).
pub type SignDotFn = fn(&[u64], &[f32], f32) -> f32;
/// `popcount(a XOR b)` summed over `min(a.len, b.len)` packed words — the
/// BNN inner product core: with activations and weights both sign-packed
/// (bit = 1 ⟺ value ≥ 0) over `k` elements and zeroed padding bits, the
/// ±1 dot product is `k - 2 * sign_xnor_dot(a, b)`. Integer result, so
/// every ISA rung is bit-exact by construction.
pub type SignXnorDotFn = fn(&[u64], &[u64]) -> u32;
/// Register-tiled panel microkernel: `panel(k, pa, pb, c, ldc, acc)`
/// computes the full `mr x nr` product of an `mr`-row LHS panel (`pa`,
/// k-major, `mr` interleaved floats per k-step) against an `nr`-column
/// RHS panel (`pb`, k-major, `nr` floats per k-step) in local register
/// accumulators, then **stores** into C rows of stride `ldc` when
/// `acc == false` or **adds** into them when `acc == true` (the k-blocked
/// driver passes `acc = kc0 > 0`). C must hold `(mr-1)*ldc + nr` floats.
/// The per-lane accumulation order over k is fixed per ISA, so a given
/// (panel, k-block) always produces identical bits.
pub type PanelFn = fn(usize, &[f32], &[f32], &mut [f32], usize, bool);

/// Upper bound on [`Kernels::mr`] across every table (the edge-tile
/// scratch and ISA-independent packing reservations are sized to these).
pub const MR_MAX: usize = 4;
/// Upper bound on [`Kernels::nr`] across every table.
pub const NR_MAX: usize = 16;

/// Upper bound on [`Kernels::sel_chunk`]: the packed engine's stack
/// accumulator strip is sized to this.
pub const SEL_CHUNK_MAX: usize = 128;

/// One ISA's microkernel table. All entries are safe `fn`s (shims over
/// the `unsafe` internals); tables are `'static`, so fetching one
/// allocates nothing.
pub struct Kernels {
    pub isa: Isa,
    pub axpy4: Axpy4Fn,
    pub axpy1: Axpy1Fn,
    pub dot: DotFn,
    pub add: AddFn,
    pub sign_accum: SignAccumFn,
    pub sign_dot: SignDotFn,
    /// XOR + popcount over packed sign words ([`SignXnorDotFn`]) — the
    /// integer inner loop of the BNN inference mode.
    pub sign_xnor_dot: SignXnorDotFn,
    /// The register-tiled f32 panel kernel ([`PanelFn`]) and its tile
    /// geometry: `mr` LHS rows by `nr` RHS columns per call. `pack_lhs` /
    /// `pack_rhs` lay panels out to exactly this geometry, so the kernel
    /// streams two contiguous buffers.
    pub panel: PanelFn,
    pub mr: usize,
    pub nr: usize,
    /// Batch-column chunk width for the packed batched kernels (<=
    /// [`SEL_CHUNK_MAX`]). AVX2 uses 64 so the whole chunk lives in
    /// eight ymm registers; scalar/SSE2/NEON gain nothing from register
    /// residency and use 128 to halve the per-column bit-decode passes.
    /// Chunking never changes results (lanes are independent columns).
    pub sel_chunk: usize,
}

static SCALAR: Kernels = Kernels {
    isa: Isa::Scalar,
    axpy4: scalar::axpy4,
    axpy1: scalar::axpy1,
    dot: scalar::dot,
    add: scalar::add,
    sign_accum: scalar::sign_accum,
    sign_dot: scalar::sign_dot,
    sign_xnor_dot: scalar::sign_xnor_dot,
    panel: scalar::panel4x8,
    mr: 4,
    nr: 8,
    sel_chunk: 128,
};

#[cfg(target_arch = "x86_64")]
static SSE2: Kernels = Kernels {
    isa: Isa::Sse2,
    axpy4: x86::sse2_axpy4,
    axpy1: x86::sse2_axpy1,
    dot: x86::sse2_dot,
    add: x86::sse2_add,
    sign_accum: x86::sse2_sign_accum,
    sign_dot: x86::sse2_sign_dot,
    sign_xnor_dot: x86::sse2_sign_xnor_dot,
    panel: x86::sse2_panel,
    mr: 4,
    nr: 8,
    sel_chunk: 128,
};

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    isa: Isa::Avx2,
    axpy4: x86::avx2_axpy4,
    axpy1: x86::avx2_axpy1,
    dot: x86::avx2_dot,
    add: x86::avx2_add,
    sign_accum: x86::avx2_sign_accum,
    sign_dot: x86::avx2_sign_dot,
    sign_xnor_dot: x86::avx2_sign_xnor_dot,
    panel: x86::avx2_panel,
    mr: 4,
    nr: 16,
    sel_chunk: 64,
};

#[cfg(target_arch = "aarch64")]
static NEON: Kernels = Kernels {
    isa: Isa::Neon,
    axpy4: aarch64::neon_axpy4,
    axpy1: aarch64::neon_axpy1,
    dot: aarch64::neon_dot,
    add: aarch64::neon_add,
    sign_accum: aarch64::neon_sign_accum,
    sign_dot: aarch64::neon_sign_dot,
    sign_xnor_dot: aarch64::neon_sign_xnor_dot,
    panel: aarch64::neon_panel,
    mr: 4,
    nr: 8,
    sel_chunk: 128,
};

/// Best rung this host can run (`is_x86_feature_detected!` on x86_64,
/// scalar elsewhere). Pure query — does not touch the selection.
pub fn detect() -> Isa {
    detect_impl()
}

#[cfg(target_arch = "x86_64")]
fn detect_impl() -> Isa {
    // POPCNT (for the avx2 sign_xnor_dot tail) predates AVX2 by several
    // generations, so requiring it never demotes a real AVX2 host — it
    // only keeps the feature set the rung's kernels compile against
    // honest.
    if is_x86_feature_detected!("avx2")
        && is_x86_feature_detected!("fma")
        && is_x86_feature_detected!("popcnt")
    {
        Isa::Avx2
    } else {
        Isa::Sse2
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_impl() -> Isa {
    // NEON is architecturally guaranteed on aarch64.
    Isa::Neon
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_impl() -> Isa {
    Isa::Scalar
}

/// The table for one specific rung, independent of the global selection
/// (the hook tests compare arms with — no process-global mutation).
///
/// # Panics
///
/// If the host cannot run `isa` (callers gate on [`Isa::supported`]).
pub fn kernels_for(isa: Isa) -> &'static Kernels {
    assert!(
        isa.supported(),
        "SIMD rung '{}' is not supported on this host (best: {})",
        isa.name(),
        detect().name()
    );
    match isa {
        Isa::Scalar => &SCALAR,
        #[cfg(target_arch = "x86_64")]
        Isa::Sse2 => &SSE2,
        #[cfg(target_arch = "x86_64")]
        Isa::Avx2 => &AVX2,
        #[cfg(target_arch = "aarch64")]
        Isa::Neon => &NEON,
        #[allow(unreachable_patterns)]
        _ => unreachable!("unsupported ISA passed the support check"),
    }
}

const ISA_UNSET: u8 = 0;

fn isa_code(isa: Isa) -> u8 {
    match isa {
        Isa::Scalar => 1,
        Isa::Sse2 => 2,
        Isa::Avx2 => 3,
        Isa::Neon => 4,
    }
}

fn isa_from_code(code: u8) -> Isa {
    match code {
        1 => Isa::Scalar,
        2 => Isa::Sse2,
        3 => Isa::Avx2,
        4 => Isa::Neon,
        _ => unreachable!("invalid ISA code {code}"),
    }
}

static ACTIVE: AtomicU8 = AtomicU8::new(ISA_UNSET);

/// The process-wide selected rung. First call resolves `BCRUN_SIMD` (an
/// invalid value panics with the parse error — `bcrun` validates the
/// variable up front to turn that into a clean CLI error instead).
pub fn active() -> Isa {
    match ACTIVE.load(Ordering::Acquire) {
        ISA_UNSET => init_active(),
        code => isa_from_code(code),
    }
}

#[cold]
fn init_active() -> Isa {
    let isa = resolve_env().unwrap_or_else(|e| panic!("{e}"));
    // A racing first use resolves the same value; last store wins.
    ACTIVE.store(isa_code(isa), Ordering::Release);
    isa
}

/// Re-point the dispatcher at `isa` (errors if the host cannot run it).
/// This is the bench hook behind `perf_gemm`'s per-ISA series; regular
/// code selects via `BCRUN_SIMD` and never calls this.
pub fn set_active(isa: Isa) -> Result<(), String> {
    if !isa.supported() {
        return Err(format!(
            "SIMD rung '{}' is not supported on this host (best: {})",
            isa.name(),
            detect().name()
        ));
    }
    ACTIVE.store(isa_code(isa), Ordering::Release);
    Ok(())
}

/// The active microkernel table (what every GEMM/packed entry point
/// fetches per call — one atomic load, no allocation).
pub fn kernels() -> &'static Kernels {
    kernels_for(active())
}

/// Pure parse of a `BCRUN_SIMD` value. `None` (unset) and `"auto"` mean
/// auto-detect; anything else must be a known rung or the error names the
/// offending value.
pub fn parse_simd(var: Option<&str>) -> Result<Option<Isa>, String> {
    match var {
        None => Ok(None),
        Some(raw) => match raw.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(None),
            "avx2" => Ok(Some(Isa::Avx2)),
            "sse2" => Ok(Some(Isa::Sse2)),
            "neon" => Ok(Some(Isa::Neon)),
            "scalar" => Ok(Some(Isa::Scalar)),
            _ => Err(format!("BCRUN_SIMD must be one of auto|avx2|sse2|neon|scalar, got '{raw}'")),
        },
    }
}

/// Parse the `BCRUN_SIMD` override from the environment (no support
/// check — see [`resolve_env`] for the full fail-fast path).
pub fn simd_from_env() -> Result<Option<Isa>, String> {
    parse_simd(env_setting("BCRUN_SIMD")?.as_deref())
}

/// Resolve `BCRUN_SIMD` to a concrete runnable rung: unset/`auto` means
/// the best detected ISA; an explicit rung must be one the host supports.
/// Checked early by `bcrun` so both typos and impossible requests fail
/// loudly instead of deep inside the first kernel.
pub fn resolve_env() -> Result<Isa, String> {
    match simd_from_env()? {
        None => Ok(detect()),
        Some(isa) if isa.supported() => Ok(isa),
        Some(isa) => Err(format!(
            "BCRUN_SIMD={} requested, but this host supports at most '{}' \
             (use BCRUN_SIMD=auto to pick it up automatically)",
            isa.name(),
            detect().name()
        )),
    }
}

/// Highest row index with a set bit in a packed column, if any. Used by
/// the SIMD shims to validate their stripe reads up front (O(words), paid
/// once per column-chunk call).
#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
pub(crate) fn highest_set_row(col: &[u64]) -> Option<usize> {
    for (wi, &word) in col.iter().enumerate().rev() {
        if word != 0 {
            return Some(wi * 64 + 63 - word.leading_zeros() as usize);
        }
    }
    None
}

/// The portable microkernels — byte-for-byte the inner loops the blocked
/// GEMM and the packed engine ran before the SIMD layer, so the scalar
/// rung *is* the historical behavior (and the oracle the property tests
/// compare every other rung against).
mod scalar {
    pub(super) fn axpy4(
        a: &[f32; 4],
        b: &[f32],
        c0: &mut [f32],
        c1: &mut [f32],
        c2: &mut [f32],
        c3: &mut [f32],
    ) {
        for ((((cv0, cv1), cv2), cv3), &bv) in c0
            .iter_mut()
            .zip(c1.iter_mut())
            .zip(c2.iter_mut())
            .zip(c3.iter_mut())
            .zip(b)
        {
            *cv0 += a[0] * bv;
            *cv1 += a[1] * bv;
            *cv2 += a[2] * bv;
            *cv3 += a[3] * bv;
        }
    }

    pub(super) fn axpy1(a: f32, b: &[f32], c: &mut [f32]) {
        for (cv, &bv) in c.iter_mut().zip(b) {
            *cv += a * bv;
        }
    }

    /// Eight-accumulator dot product; fixed reduction order (chunks of 8,
    /// then pairwise fold, then the tail) so every call site agrees
    /// bit-for-bit.
    pub(super) fn dot(a: &[f32], b: &[f32]) -> f32 {
        let mut acc = [0f32; 8];
        let mut ac = a.chunks_exact(8);
        let mut bc = b.chunks_exact(8);
        for (av, bv) in (&mut ac).zip(&mut bc) {
            for ((s, &x), &y) in acc.iter_mut().zip(av).zip(bv) {
                *s += x * y;
            }
        }
        let mut s = ((acc[0] + acc[1]) + (acc[2] + acc[3]))
            + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
        for (&av, &bv) in ac.remainder().iter().zip(bc.remainder()) {
            s += av * bv;
        }
        s
    }

    pub(super) fn add(dst: &mut [f32], src: &[f32]) {
        for (d, &s) in dst.iter_mut().zip(src) {
            *d += s;
        }
    }

    pub(super) fn sign_accum(col: &[u64], xt: &[f32], b: usize, c0: usize, sel: &mut [f32]) {
        let len = sel.len();
        for (wi, &word) in col.iter().enumerate() {
            if word == 0 {
                continue;
            }
            let base = wi * 64;
            let mut m = word;
            while m != 0 {
                let t = m.trailing_zeros() as usize;
                let off = (base + t) * b + c0;
                let stripe = &xt[off..off + len];
                for (s, &v) in sel.iter_mut().zip(stripe) {
                    *s += v;
                }
                m &= m - 1;
            }
        }
    }

    /// Portable 4x8 panel microkernel (see [`super::PanelFn`]): the
    /// whole C tile lives in a local array the optimizer keeps in
    /// registers; one pass over k, then a single store/add sweep.
    pub(super) fn panel4x8(k: usize, pa: &[f32], pb: &[f32], c: &mut [f32], ldc: usize, acc: bool) {
        const MR: usize = 4;
        const NR: usize = 8;
        assert!(pa.len() >= k * MR, "panel4x8: packed LHS too short");
        assert!(pb.len() >= k * NR, "panel4x8: packed RHS too short");
        assert!(ldc >= NR && c.len() >= (MR - 1) * ldc + NR, "panel4x8: C tile out of range");
        let mut t = [[0f32; NR]; MR];
        for kk in 0..k {
            let av = &pa[kk * MR..kk * MR + MR];
            let bv = &pb[kk * NR..kk * NR + NR];
            for (tr, &ar) in t.iter_mut().zip(av) {
                for (tv, &bj) in tr.iter_mut().zip(bv) {
                    *tv += ar * bj;
                }
            }
        }
        for (r, tr) in t.iter().enumerate() {
            let crow = &mut c[r * ldc..r * ldc + NR];
            if acc {
                for (cv, &tv) in crow.iter_mut().zip(tr) {
                    *cv += tv;
                }
            } else {
                crow.copy_from_slice(tr);
            }
        }
    }

    /// Portable XOR–popcount reduction; `u64::count_ones` lowers to a
    /// single `popcnt`-class instruction where the baseline target has
    /// one, SWAR otherwise. Integer sum, so associativity is free and
    /// every other rung must match this bit-for-bit.
    pub(super) fn sign_xnor_dot(a: &[u64], b: &[u64]) -> u32 {
        a.iter().zip(b).map(|(&x, &y)| (x ^ y).count_ones()).sum()
    }

    pub(super) fn sign_dot(col: &[u64], x: &[f32], total: f32) -> f32 {
        let k = x.len();
        let mut sel = 0f32;
        // selected-sum: adds only, gated by the weight bits
        for (wi, &word) in col.iter().enumerate() {
            if word == 0 {
                continue;
            }
            let base = wi * 64;
            if word == u64::MAX && base + 64 <= k {
                // fast path: fully-positive word
                for &v in &x[base..base + 64] {
                    sel += v;
                }
            } else {
                let mut m = word;
                while m != 0 {
                    let t = m.trailing_zeros() as usize;
                    sel += x[base + t];
                    m &= m - 1;
                }
            }
        }
        2.0 * sel - total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..len).map(|_| rng.normal()).collect()
    }

    #[test]
    fn parse_is_validated() {
        assert_eq!(parse_simd(None), Ok(None));
        assert_eq!(parse_simd(Some("auto")), Ok(None));
        assert_eq!(parse_simd(Some(" AVX2 ")), Ok(Some(Isa::Avx2)));
        assert_eq!(parse_simd(Some("sse2")), Ok(Some(Isa::Sse2)));
        assert_eq!(parse_simd(Some("neon")), Ok(Some(Isa::Neon)));
        assert_eq!(parse_simd(Some(" NEON ")), Ok(Some(Isa::Neon)));
        assert_eq!(parse_simd(Some("scalar")), Ok(Some(Isa::Scalar)));
        for bad in ["", "avx512", "sve", "yes", "1"] {
            let err = parse_simd(Some(bad)).unwrap_err();
            // the quoted form is non-vacuous even for the empty string
            assert!(
                err.contains("auto|avx2|sse2|neon|scalar") && err.contains(&format!("'{bad}'")),
                "unhelpful error for {bad:?}: {err}"
            );
        }
    }

    #[test]
    fn neon_is_gated_on_aarch64() {
        assert_eq!(Isa::Neon.supported(), cfg!(target_arch = "aarch64"));
        if !Isa::Neon.supported() {
            // requesting the rung anywhere must fail fast, same as an
            // unsupported avx2 request: both the bench hook and the
            // BCRUN_SIMD resolution path refuse it with a named error.
            let err = set_active(Isa::Neon).unwrap_err();
            assert!(err.contains("neon"), "error should name the rung: {err}");
        } else {
            assert_eq!(kernels_for(Isa::Neon).isa, Isa::Neon);
            assert_eq!(detect(), Isa::Neon);
        }
    }

    #[test]
    fn scalar_is_always_supported_and_detect_is_runnable() {
        assert!(Isa::Scalar.supported());
        assert!(detect().supported());
        assert!(ALL_ISAS.iter().any(|i| i.supported()));
        // the active selection resolves to something runnable
        assert!(active().supported());
        assert_eq!(kernels().isa, active());
    }

    #[test]
    #[cfg(target_arch = "x86_64")]
    fn sse2_is_baseline_on_x86_64() {
        assert!(Isa::Sse2.supported());
        assert_eq!(kernels_for(Isa::Sse2).isa, Isa::Sse2);
    }

    #[test]
    fn panel_microkernel_matches_reference_on_every_arm() {
        // ragged k values, both store (acc=false) and accumulate
        // (acc=true), wide-ldc C to catch stride bugs
        for &k in &[0usize, 1, 3, 8, 17, 64, 65] {
            for isa in ALL_ISAS.iter().filter(|i| i.supported()) {
                let kern = kernels_for(*isa);
                let (mr, nr) = (kern.mr, kern.nr);
                assert!(mr <= MR_MAX && nr <= NR_MAX, "{isa:?} geometry exceeds maxima");
                let pa = rand(k * mr, 1000 + k as u64);
                let pb = rand(k * nr, 2000 + k as u64);
                let ldc = nr + 3;
                let init = rand(mr * ldc, 3000 + k as u64);
                for acc in [false, true] {
                    let mut c = init.clone();
                    (kern.panel)(k, &pa, &pb, &mut c, ldc, acc);
                    for r in 0..mr {
                        for j in 0..nr {
                            let mut want: f64 = if acc { init[r * ldc + j] as f64 } else { 0.0 };
                            for kk in 0..k {
                                want += pa[kk * mr + r] as f64 * pb[kk * nr + j] as f64;
                            }
                            let got = c[r * ldc + j] as f64;
                            assert!(
                                (got - want).abs() < 1e-4 * (1.0 + want.abs()),
                                "{isa:?} panel k={k} acc={acc} [{r},{j}]: {got} vs {want}"
                            );
                        }
                        // lanes past nr are untouched
                        for j in nr..ldc {
                            assert_eq!(c[r * ldc + j], init[r * ldc + j], "{isa:?} clobbered ldc gap");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn sign_xnor_dot_is_bit_exact_across_arms() {
        // word counts straddling every vector width in the tables:
        // sub-block (1..3), exact AVX2 blocks (4, 8), ragged tails
        // (5, 7, 9, 17), and empty input.
        let mut rng = Rng::new(42);
        for &words in &[0usize, 1, 2, 3, 4, 5, 7, 8, 9, 16, 17, 33] {
            let a: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
            let want: u32 = a.iter().zip(&b).map(|(&x, &y)| (x ^ y).count_ones()).sum();
            for isa in ALL_ISAS.iter().filter(|i| i.supported()) {
                let got = (kernels_for(*isa).sign_xnor_dot)(&a, &b);
                assert_eq!(got, want, "{isa:?} sign_xnor_dot mismatch at {words} words");
                // all-equal inputs -> zero, all-complement -> every bit
                let c: Vec<u64> = a.iter().map(|&x| !x).collect();
                assert_eq!((kernels_for(*isa).sign_xnor_dot)(&a, &a), 0, "{isa:?} self-xor");
                assert_eq!(
                    (kernels_for(*isa).sign_xnor_dot)(&a, &c),
                    64 * words as u32,
                    "{isa:?} complement"
                );
            }
        }
    }

    #[test]
    fn dot_fixed_order_is_stable() {
        let a = rand(37, 7);
        let b = rand(37, 8);
        for isa in ALL_ISAS.iter().filter(|i| i.supported()) {
            let dot = kernels_for(*isa).dot;
            assert_eq!(dot(&a, &b), dot(&a, &b), "{isa:?} dot not deterministic");
            let want: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let got = dot(&a, &b) as f64;
            assert!((got - want).abs() < 1e-3 * (1.0 + want.abs()), "{isa:?}: {got} vs {want}");
        }
    }

    #[test]
    fn every_supported_arm_runs_the_microkernels() {
        // tail-heavy lengths: 1, 7 (sub-lane), 8, 9, 63, 64, 65
        for &n in &[1usize, 7, 8, 9, 63, 64, 65] {
            let b = rand(n, 100 + n as u64);
            let a = [0.5f32, -1.25, 0.0, 2.0];
            for isa in ALL_ISAS.iter().filter(|i| i.supported()) {
                let kern = kernels_for(*isa);
                let mut c: Vec<Vec<f32>> = (0..4).map(|r| rand(n, 200 + r as u64)).collect();
                let mut want = c.clone();
                for (r, w) in want.iter_mut().enumerate() {
                    for (wv, &bv) in w.iter_mut().zip(&b) {
                        *wv += a[r] * bv;
                    }
                }
                let (h0, h1) = c.split_at_mut(2);
                let (c0, c1) = h0.split_at_mut(1);
                let (c2, c3) = h1.split_at_mut(1);
                (kern.axpy4)(&a, &b, &mut c0[0], &mut c1[0], &mut c2[0], &mut c3[0]);
                for (r, w) in want.iter().enumerate() {
                    for (j, (&got, &wv)) in c[r].iter().zip(w).enumerate() {
                        assert!(
                            (got - wv).abs() < 1e-5 * (1.0 + wv.abs()),
                            "{isa:?} axpy4 row {r} [{j}]: {got} vs {wv}"
                        );
                    }
                }
                // axpy1 agrees with row 1 of axpy4's math
                let mut c1a = rand(n, 201);
                let mut w1 = c1a.clone();
                for (wv, &bv) in w1.iter_mut().zip(&b) {
                    *wv += a[1] * bv;
                }
                (kern.axpy1)(a[1], &b, &mut c1a);
                for (j, (&got, &wv)) in c1a.iter().zip(&w1).enumerate() {
                    assert!(
                        (got - wv).abs() < 1e-5 * (1.0 + wv.abs()),
                        "{isa:?} axpy1 [{j}]: {got} vs {wv}"
                    );
                }
                // add is bit-exact across arms (independent lanes)
                let mut d = rand(n, 300);
                let src = rand(n, 301);
                let mut dw = d.clone();
                scalar::add(&mut dw, &src);
                (kern.add)(&mut d, &src);
                assert_eq!(d, dw, "{isa:?} add must be bit-exact");
            }
        }
    }
}
