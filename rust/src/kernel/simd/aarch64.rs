//! aarch64 NEON microkernels. NEON (Advanced SIMD) is architecturally
//! guaranteed on aarch64, so — like SSE2 on x86_64 — no runtime feature
//! detection is needed; the table is reachable whenever this file
//! compiles in.
//!
//! Same shim contract as `x86.rs`: each `pub(super)` shim is a *safe*
//! `fn` matching the [`super::Kernels`] table signature, derives its
//! element counts from the slices it was handed, then calls the `unsafe`
//! raw-pointer inner kernel.
//!
//! Exactness (pinned by `tests/simd_kernels.rs` on an aarch64 host and by
//! the cross-target CI check lane elsewhere):
//! * `neon_add` / `neon_sign_accum` are bit-exact with scalar —
//!   independent lanes, identical per-lane add order.
//! * `neon_axpy1` and row `r` of `neon_axpy4` use the same
//!   vector-vs-tail boundary, keeping pooled and serial GEMMs equal.
//! * `neon_dot` / `neon_sign_dot` / `neon_panel` have fixed per-call
//!   reduction orders (deterministic), equal to scalar within the
//!   1e-5-scale association bound.

use std::arch::aarch64::*;

pub(super) fn neon_axpy4(
    a: &[f32; 4],
    b: &[f32],
    c0: &mut [f32],
    c1: &mut [f32],
    c2: &mut [f32],
    c3: &mut [f32],
) {
    let n = b.len().min(c0.len()).min(c1.len()).min(c2.len()).min(c3.len());
    // SAFETY: NEON is baseline on aarch64; every offset below is < n,
    // which is within all six slices by the min above.
    unsafe {
        axpy4_neon(
            a,
            b.as_ptr(),
            c0.as_mut_ptr(),
            c1.as_mut_ptr(),
            c2.as_mut_ptr(),
            c3.as_mut_ptr(),
            n,
        )
    }
}

unsafe fn axpy4_neon(
    a: &[f32; 4],
    b: *const f32,
    c0: *mut f32,
    c1: *mut f32,
    c2: *mut f32,
    c3: *mut f32,
    n: usize,
) {
    let mut j = 0usize;
    while j + 4 <= n {
        let vb = vld1q_f32(b.add(j));
        vst1q_f32(c0.add(j), vfmaq_n_f32(vld1q_f32(c0.add(j)), vb, a[0]));
        vst1q_f32(c1.add(j), vfmaq_n_f32(vld1q_f32(c1.add(j)), vb, a[1]));
        vst1q_f32(c2.add(j), vfmaq_n_f32(vld1q_f32(c2.add(j)), vb, a[2]));
        vst1q_f32(c3.add(j), vfmaq_n_f32(vld1q_f32(c3.add(j)), vb, a[3]));
        j += 4;
    }
    while j < n {
        let bv = *b.add(j);
        *c0.add(j) += a[0] * bv;
        *c1.add(j) += a[1] * bv;
        *c2.add(j) += a[2] * bv;
        *c3.add(j) += a[3] * bv;
        j += 1;
    }
}

pub(super) fn neon_axpy1(a: f32, b: &[f32], c: &mut [f32]) {
    let n = b.len().min(c.len());
    // SAFETY: NEON baseline; offsets < n are in bounds of both slices.
    unsafe { axpy1_neon(a, b.as_ptr(), c.as_mut_ptr(), n) }
}

unsafe fn axpy1_neon(a: f32, b: *const f32, c: *mut f32, n: usize) {
    let mut j = 0usize;
    while j + 8 <= n {
        let v0 = vfmaq_n_f32(vld1q_f32(c.add(j)), vld1q_f32(b.add(j)), a);
        vst1q_f32(c.add(j), v0);
        let v1 = vfmaq_n_f32(vld1q_f32(c.add(j + 4)), vld1q_f32(b.add(j + 4)), a);
        vst1q_f32(c.add(j + 4), v1);
        j += 8;
    }
    while j + 4 <= n {
        let v0 = vfmaq_n_f32(vld1q_f32(c.add(j)), vld1q_f32(b.add(j)), a);
        vst1q_f32(c.add(j), v0);
        j += 4;
    }
    while j < n {
        *c.add(j) += a * *b.add(j);
        j += 1;
    }
}

pub(super) fn neon_dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    // SAFETY: NEON baseline; reads stay below n.
    unsafe { dot_neon(a.as_ptr(), b.as_ptr(), n) }
}

unsafe fn dot_neon(a: *const f32, b: *const f32, n: usize) -> f32 {
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut acc2 = vdupq_n_f32(0.0);
    let mut acc3 = vdupq_n_f32(0.0);
    let mut j = 0usize;
    while j + 16 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(a.add(j)), vld1q_f32(b.add(j)));
        acc1 = vfmaq_f32(acc1, vld1q_f32(a.add(j + 4)), vld1q_f32(b.add(j + 4)));
        acc2 = vfmaq_f32(acc2, vld1q_f32(a.add(j + 8)), vld1q_f32(b.add(j + 8)));
        acc3 = vfmaq_f32(acc3, vld1q_f32(a.add(j + 12)), vld1q_f32(b.add(j + 12)));
        j += 16;
    }
    while j + 4 <= n {
        acc0 = vfmaq_f32(acc0, vld1q_f32(a.add(j)), vld1q_f32(b.add(j)));
        j += 4;
    }
    let mut s = vaddvq_f32(vaddq_f32(vaddq_f32(acc0, acc1), vaddq_f32(acc2, acc3)));
    while j < n {
        s += *a.add(j) * *b.add(j);
        j += 1;
    }
    s
}

pub(super) fn neon_add(dst: &mut [f32], src: &[f32]) {
    let n = dst.len().min(src.len());
    // SAFETY: NEON baseline; offsets < n are within both slices.
    unsafe { add_neon(dst.as_mut_ptr(), src.as_ptr(), n) }
}

unsafe fn add_neon(dst: *mut f32, src: *const f32, n: usize) {
    let mut j = 0usize;
    while j + 4 <= n {
        vst1q_f32(dst.add(j), vaddq_f32(vld1q_f32(dst.add(j)), vld1q_f32(src.add(j))));
        j += 4;
    }
    while j < n {
        *dst.add(j) += *src.add(j);
        j += 1;
    }
}

pub(super) fn neon_sign_accum(col: &[u64], xt: &[f32], b: usize, c0: usize, sel: &mut [f32]) {
    if let Some(r) = super::highest_set_row(col) {
        assert!(r * b + c0 + sel.len() <= xt.len(), "sign_accum: stripe out of bounds");
    }
    // SAFETY: the assert above bounds every stripe the inner kernel
    // reads (bits only reach rows <= highest_set_row); sel writes stay
    // below sel.len(). NEON baseline.
    unsafe { sign_accum_neon(col, xt.as_ptr(), b, c0, sel) }
}

unsafe fn sign_accum_neon(col: &[u64], xt: *const f32, b: usize, c0: usize, sel: &mut [f32]) {
    let len = sel.len();
    let sp = sel.as_mut_ptr();
    for (wi, &word) in col.iter().enumerate() {
        if word == 0 {
            continue;
        }
        let base = wi * 64;
        let mut m = word;
        while m != 0 {
            let t = m.trailing_zeros() as usize;
            let xp = xt.add((base + t) * b + c0);
            let mut c = 0usize;
            while c + 4 <= len {
                vst1q_f32(sp.add(c), vaddq_f32(vld1q_f32(sp.add(c)), vld1q_f32(xp.add(c))));
                c += 4;
            }
            while c < len {
                *sp.add(c) += *xp.add(c);
                c += 1;
            }
            m &= m - 1;
        }
    }
}

pub(super) fn neon_sign_dot(col: &[u64], x: &[f32], _total: f32) -> f32 {
    assert!(col.len() * 64 >= x.len(), "sign_dot: packed column too short");
    // SAFETY: reads of x stay below x.len(); word reads stay below
    // col.len() by the assert. NEON baseline.
    unsafe { sign_dot_neon(col, x.as_ptr(), x.len()) }
}

unsafe fn sign_dot_neon(col: &[u64], x: *const f32, k: usize) -> f32 {
    // lane j of a 4-wide block tests weight bit j of the broadcast
    // nibble; bit 0 (weight -1) flips the lane's sign via XOR with
    // 0x8000_0000 — the same bit trick as the x86 rungs.
    let lane: uint32x4_t = vld1q_u32([1u32, 2, 4, 8].as_ptr());
    let signbit = vdupq_n_u32(0x8000_0000);
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut r = 0usize;
    while r + 8 <= k {
        let b0 = vdupq_n_u32(((*col.get_unchecked(r >> 6) >> (r & 63)) & 0xf) as u32);
        let b1 = vdupq_n_u32(((*col.get_unchecked((r + 4) >> 6) >> ((r + 4) & 63)) & 0xf) as u32);
        let f0 = vbicq_u32(signbit, vceqq_u32(vandq_u32(b0, lane), lane));
        let f1 = vbicq_u32(signbit, vceqq_u32(vandq_u32(b1, lane), lane));
        let v0 = veorq_u32(vreinterpretq_u32_f32(vld1q_f32(x.add(r))), f0);
        let v1 = veorq_u32(vreinterpretq_u32_f32(vld1q_f32(x.add(r + 4))), f1);
        acc0 = vaddq_f32(acc0, vreinterpretq_f32_u32(v0));
        acc1 = vaddq_f32(acc1, vreinterpretq_f32_u32(v1));
        r += 8;
    }
    if r + 4 <= k {
        let b0 = vdupq_n_u32(((*col.get_unchecked(r >> 6) >> (r & 63)) & 0xf) as u32);
        let f0 = vbicq_u32(signbit, vceqq_u32(vandq_u32(b0, lane), lane));
        let v0 = veorq_u32(vreinterpretq_u32_f32(vld1q_f32(x.add(r))), f0);
        acc0 = vaddq_f32(acc0, vreinterpretq_f32_u32(v0));
        r += 4;
    }
    let mut s = vaddvq_f32(vaddq_f32(acc0, acc1));
    while r < k {
        let bit = (*col.get_unchecked(r >> 6) >> (r & 63)) & 1;
        let v = *x.add(r);
        s += if bit == 1 { v } else { -v };
        r += 1;
    }
    s
}

pub(super) fn neon_sign_xnor_dot(a: &[u64], b: &[u64]) -> u32 {
    let n = a.len().min(b.len());
    // SAFETY: NEON baseline; reads stay below n in both slices.
    unsafe { sign_xnor_dot_neon(a.as_ptr(), b.as_ptr(), n) }
}

unsafe fn sign_xnor_dot_neon(a: *const u64, b: *const u64, n: usize) -> u32 {
    // Per 2-word block: XOR, per-byte popcount (vcnt), widening
    // horizontal add (16 byte counts ≤ 8 each, so the u16 sum ≤ 128
    // never overflows). Integer throughout — bit-exact with scalar.
    let mut s = 0u32;
    let mut i = 0usize;
    while i + 2 <= n {
        let va = vld1q_u64(a.add(i));
        let vb = vld1q_u64(b.add(i));
        let x = veorq_u64(va, vb);
        let cnt = vcntq_u8(vreinterpretq_u8_u64(x));
        s += vaddlvq_u8(cnt) as u32;
        i += 2;
    }
    while i < n {
        s += (*a.add(i) ^ *b.add(i)).count_ones();
        i += 1;
    }
    s
}

pub(super) fn neon_panel(k: usize, pa: &[f32], pb: &[f32], c: &mut [f32], ldc: usize, acc: bool) {
    const MR: usize = 4;
    const NR: usize = 8;
    assert!(pa.len() >= k * MR, "neon_panel: packed LHS too short");
    assert!(pb.len() >= k * NR, "neon_panel: packed RHS too short");
    assert!(ldc >= NR && c.len() >= (MR - 1) * ldc + NR, "neon_panel: C tile out of range");
    // SAFETY: NEON baseline; the asserts bound every pa/pb read at
    // k*MR / k*NR and every C access at row r's [r*ldc, r*ldc+NR).
    unsafe { panel_neon(k, pa.as_ptr(), pb.as_ptr(), c.as_mut_ptr(), ldc, acc) }
}

unsafe fn panel_neon(k: usize, pa: *const f32, pb: *const f32, c: *mut f32, ldc: usize, acc: bool) {
    // 4x8 tile in eight q-register accumulators: acc{r}{h} covers row r,
    // columns h*4 .. h*4+4; vfmaq_n_f32 broadcasts the packed A value.
    let mut a00 = vdupq_n_f32(0.0);
    let mut a01 = vdupq_n_f32(0.0);
    let mut a10 = vdupq_n_f32(0.0);
    let mut a11 = vdupq_n_f32(0.0);
    let mut a20 = vdupq_n_f32(0.0);
    let mut a21 = vdupq_n_f32(0.0);
    let mut a30 = vdupq_n_f32(0.0);
    let mut a31 = vdupq_n_f32(0.0);
    for kk in 0..k {
        let ap = pa.add(kk * 4);
        let bp = pb.add(kk * 8);
        let b0 = vld1q_f32(bp);
        let b1 = vld1q_f32(bp.add(4));
        let v0 = *ap;
        a00 = vfmaq_n_f32(a00, b0, v0);
        a01 = vfmaq_n_f32(a01, b1, v0);
        let v1 = *ap.add(1);
        a10 = vfmaq_n_f32(a10, b0, v1);
        a11 = vfmaq_n_f32(a11, b1, v1);
        let v2 = *ap.add(2);
        a20 = vfmaq_n_f32(a20, b0, v2);
        a21 = vfmaq_n_f32(a21, b1, v2);
        let v3 = *ap.add(3);
        a30 = vfmaq_n_f32(a30, b0, v3);
        a31 = vfmaq_n_f32(a31, b1, v3);
    }
    let rows = [[a00, a01], [a10, a11], [a20, a21], [a30, a31]];
    for (r, half) in rows.iter().enumerate() {
        let cp = c.add(r * ldc);
        if acc {
            vst1q_f32(cp, vaddq_f32(vld1q_f32(cp), half[0]));
            vst1q_f32(cp.add(4), vaddq_f32(vld1q_f32(cp.add(4)), half[1]));
        } else {
            vst1q_f32(cp, half[0]);
            vst1q_f32(cp.add(4), half[1]);
        }
    }
}
