//! Learning-rate schedules. The paper uses an exponentially decaying LR in
//! every experiment (Sec. 3.1-3.3): lr_e = lr_0 * (lr_E / lr_0)^(e / (E-1)).

#[derive(Clone, Copy, Debug)]
pub enum LrSchedule {
    Constant { lr: f32 },
    /// Exponential decay from `start` at epoch 0 to `end` at the last epoch.
    Exponential { start: f32, end: f32, epochs: usize },
}

impl LrSchedule {
    pub fn at(&self, epoch: usize) -> f32 {
        match *self {
            LrSchedule::Constant { lr } => lr,
            LrSchedule::Exponential { start, end, epochs } => {
                if epochs <= 1 {
                    return start;
                }
                let t = epoch.min(epochs - 1) as f64 / (epochs - 1) as f64;
                (start as f64 * (end as f64 / start as f64).powf(t)) as f32
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::Constant { lr: 0.1 };
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(100), 0.1);
    }

    #[test]
    fn exponential_hits_endpoints() {
        let s = LrSchedule::Exponential { start: 0.1, end: 0.001, epochs: 11 };
        assert!((s.at(0) - 0.1).abs() < 1e-7);
        assert!((s.at(10) - 0.001).abs() < 1e-7);
        assert!((s.at(999) - 0.001).abs() < 1e-7); // clamps past the end
    }

    #[test]
    fn exponential_monotone_decreasing() {
        let s = LrSchedule::Exponential { start: 0.3, end: 0.003, epochs: 50 };
        for e in 1..50 {
            assert!(s.at(e) < s.at(e - 1));
        }
    }

    #[test]
    fn geometric_ratio_constant() {
        let s = LrSchedule::Exponential { start: 1.0, end: 0.01, epochs: 21 };
        let r0 = s.at(1) / s.at(0);
        let r1 = s.at(11) / s.at(10);
        assert!((r0 - r1).abs() < 1e-5);
    }

    #[test]
    fn single_epoch_uses_start() {
        let s = LrSchedule::Exponential { start: 0.5, end: 0.1, epochs: 1 };
        assert_eq!(s.at(0), 0.5);
    }
}
