//! Shared experiment protocol: dataset preparation per the paper's
//! per-corpus pipeline, and the canonical hyperparameter presets used by
//! the examples and the table/figure benches.

use std::path::Path;

use crate::anyhow;
use crate::util::error::Result;

use crate::data::{load_or_synth, Corpus, SplitData};
use crate::preprocess::{gcn, Standardizer, Zca};
use crate::runtime::{Mode, Opt};

use super::schedule::LrSchedule;
use super::trainer::TrainOpts;

/// Dataset preparation options.
#[derive(Clone, Debug)]
pub struct DataOpts {
    pub data_dir: Option<std::path::PathBuf>,
    pub n_train: usize,
    pub n_test: usize,
    pub zca: bool,
    /// covariance-fit subsample bound (0 = all rows).
    pub zca_samples: usize,
    /// ZCA regularizer added to every eigenvalue. With n_fit << d the
    /// sample covariance is low-rank and out-of-span test energy is scaled
    /// by 1/sqrt(eps); keep eps large enough (>= ~1 after unit-contrast
    /// GCN) unless the fit uses >= d samples.
    pub zca_eps: f64,
    pub seed: u64,
}

impl Default for DataOpts {
    fn default() -> Self {
        Self {
            data_dir: None,
            n_train: 0,
            n_test: 0,
            zca: true,
            zca_samples: 4000,
            // default suits the CPU-scale regime n_fit << d = 3072 (after
            // unit-contrast GCN); measured: eps 0.5 / 1.0 / 3.0 -> test err
            // 32.5% / 7.0% / 0.25% on the synthetic CIFAR CNN baseline.
            // Lower toward 0.1 when fitting on >= d samples (real corpora).
            zca_eps: 3.0,
            seed: 7,
        }
    }
}

/// Load + preprocess a corpus exactly as the paper does (Sec. 3):
/// MNIST — raw pixels, per-feature standardization, val = tail of train;
/// CIFAR-10 / SVHN — global contrast normalization + ZCA whitening.
pub fn prepare(corpus: Corpus, opts: &DataOpts) -> Result<(SplitData, bool)> {
    let (mut train, mut test, real) = load_or_synth(
        corpus,
        opts.data_dir.as_deref().map(Path::new),
        opts.n_train,
        opts.n_test,
        opts.seed,
    );
    match corpus {
        Corpus::Mnist => {
            let st = Standardizer::fit(&train);
            st.apply(&mut train);
            st.apply(&mut test);
        }
        Corpus::Cifar10 | Corpus::Svhn => {
            gcn(&mut train, 1.0, 1e-8);
            gcn(&mut test, 1.0, 1e-8);
            if opts.zca {
                let zca =
                    Zca::fit(&train, opts.zca_eps, opts.zca_samples).map_err(|e| anyhow!(e))?;
                zca.apply(&mut train);
                zca.apply(&mut test);
            }
        }
    }
    let n_val = ((train.len() as f64) * corpus.val_fraction()).round() as usize;
    let n_val = n_val.clamp(1, train.len() - 1);
    Ok((SplitData::from_train_test(train, test, n_val), real))
}

/// The paper's MNIST protocol (Sec. 3.1): SGD without momentum,
/// exponentially decaying LR. LR presets found by a coarse sweep on the
/// synthetic stand-in (EXPERIMENTS.md records them per run).
pub fn mnist_opts(mode: Mode, epochs: usize, seed: u64) -> TrainOpts {
    TrainOpts {
        epochs,
        schedule: LrSchedule::Exponential { start: 0.003, end: 0.0002, epochs },
        mode,
        opt: Opt::Sgd,
        lr_scale: true,
        seed,
        verbose: false,
        ..Default::default()
    }
}

/// The paper's CIFAR-10 / SVHN protocol (Sec. 3.2-3.3): ADAM + BN +
/// exponentially decaying LR.
pub fn cnn_opts(mode: Mode, epochs: usize, seed: u64) -> TrainOpts {
    TrainOpts {
        epochs,
        schedule: LrSchedule::Exponential { start: 0.002, end: 0.0002, epochs },
        mode,
        opt: Opt::Adam,
        lr_scale: true,
        seed,
        verbose: false,
        ..Default::default()
    }
}

/// The 50%-dropout baseline row of Table 2.
pub fn dropout_opts(base: &TrainOpts) -> TrainOpts {
    TrainOpts { mode: Mode::None, dropout: 0.5, ..base.clone() }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prepare_mnist_standardizes() {
        let (data, real) = prepare(
            Corpus::Mnist,
            &DataOpts { n_train: 200, n_test: 50, ..Default::default() },
        )
        .unwrap();
        assert!(!real);
        assert_eq!(data.train.len() + data.val.len(), 200);
        // standardized features: overall mean near 0
        let mean: f32 =
            data.train.x.iter().sum::<f32>() / data.train.x.len() as f32;
        assert!(mean.abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn prepare_cifar_whitens() {
        let (data, _) = prepare(
            Corpus::Cifar10,
            &DataOpts { n_train: 120, n_test: 30, zca_samples: 120, ..Default::default() },
        )
        .unwrap();
        assert_eq!(data.test.len(), 30);
        assert!(data.train.x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn prepare_cifar_no_zca_is_faster_path() {
        let (data, _) = prepare(
            Corpus::Cifar10,
            &DataOpts { n_train: 60, n_test: 20, zca: false, ..Default::default() },
        )
        .unwrap();
        assert_eq!(data.train.len() + data.val.len(), 60);
    }

    #[test]
    fn presets_follow_paper() {
        let m = mnist_opts(Mode::Stoch, 10, 1);
        assert_eq!(m.opt, Opt::Sgd); // Sec. 3.1: SGD without momentum
        let c = cnn_opts(Mode::Det, 10, 1);
        assert_eq!(c.opt, Opt::Adam); // Sec. 3.2: ADAM
        let d = dropout_opts(&m);
        assert_eq!(d.mode, Mode::None);
        assert_eq!(d.dropout, 0.5);
    }
}
