//! The training loop (Algorithm 1 driven at full-epoch granularity) and
//! multi-seed trial aggregation.
//!
//! Backend-agnostic: everything goes through the [`Executor`] trait, so
//! the same loop drives the pure-Rust reference backend and (with the
//! `pjrt` feature) the PJRT artifact path.

use crate::data::SplitData;
use crate::pipeline::{Plan, Prefetcher};
use crate::runtime::{Executor, Hyper, Mode, Opt, TrainState};
use crate::stats::mean_std;
use crate::util::error::Result;
use crate::util::{Rng, Timer};

use super::schedule::LrSchedule;

/// Everything one training run needs (one Table-1/Table-2 cell).
#[derive(Clone, Debug)]
pub struct TrainOpts {
    pub epochs: usize,
    pub schedule: LrSchedule,
    pub mode: Mode,
    pub opt: Opt,
    pub momentum: f32,
    pub beta2: f32,
    pub eps: f32,
    pub dropout: f32,
    pub in_dropout: f32,
    pub bn_momentum: f32,
    pub lr_scale: bool,
    pub seed: u64,
    /// early-stopping patience in epochs (0 = never stop early).
    pub patience: usize,
    /// print per-epoch progress lines.
    pub verbose: bool,
    /// override the Sec.-2.6 default test-time weight mode (e.g. evaluate
    /// a stochastically-trained net by sampling w_b — alternative 3 —
    /// which keeps the BN statistics calibrated at short training).
    pub eval_override: Option<Mode>,
}

impl Default for TrainOpts {
    fn default() -> Self {
        Self {
            epochs: 20,
            schedule: LrSchedule::Exponential { start: 0.02, end: 0.002, epochs: 20 },
            mode: Mode::Det,
            opt: Opt::Sgd,
            momentum: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            dropout: 0.0,
            in_dropout: 0.0,
            bn_momentum: 0.9,
            lr_scale: true,
            seed: 1,
            patience: 0,
            verbose: false,
            eval_override: None,
        }
    }
}

impl TrainOpts {
    /// Test-time inference mode per paper Sec. 2.6: deterministic BC uses
    /// the binary weights (method 1); stochastic BC and the baselines use
    /// the real-valued weights (method 2). `eval_override` selects
    /// alternative 3 (stochastic sampling) or any other mode explicitly.
    pub fn eval_mode(&self) -> Mode {
        if let Some(m) = self.eval_override {
            return m;
        }
        match self.mode {
            Mode::Det => Mode::Det,
            _ => Mode::None,
        }
    }
}

/// Per-epoch curve record (Figure 3's series).
#[derive(Clone, Copy, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub lr: f32,
    pub train_loss: f64,
    pub train_err: f64,
    pub val_err: f64,
    pub seconds: f64,
}

/// Outcome of one run.
pub struct RunResult {
    pub curves: Vec<EpochRecord>,
    pub best_epoch: usize,
    pub best_val_err: f64,
    /// test error at the best-validation epoch (paper protocol).
    pub test_err: f64,
    pub state: TrainState,
    pub steps: usize,
    pub total_seconds: f64,
}

/// Evaluate a dataset (padded batching), masked to valid examples.
pub fn evaluate(
    model: &dyn Executor,
    state: &TrainState,
    ds: &crate::data::Dataset,
    hyper: &Hyper,
) -> Result<(f64, f64)> {
    let batch = model.info().batch;
    let mut pf = Prefetcher::spawn(ds, batch, Plan::Sequential, 2);
    let mut loss_sum = 0f64;
    let mut err_sum = 0f64;
    let mut n = 0usize;
    while let Some(b) = pf.next() {
        let (lossv, errv) = model.eval_batch(state, &b.x, &b.y, hyper)?;
        for i in 0..b.n_valid {
            loss_sum += lossv[i] as f64;
            err_sum += errv[i] as f64;
        }
        n += b.n_valid;
    }
    let n = n.max(1) as f64;
    Ok((loss_sum / n, err_sum / n))
}

/// Train one model per the paper's protocol.
pub fn train(model: &dyn Executor, data: &SplitData, opts: &TrainOpts) -> Result<RunResult> {
    let total = Timer::start();
    let mut rng = Rng::new(opts.seed);
    let init_hyper = Hyper { seed: (opts.seed & 0xFF_FFFF) as u32, ..Default::default() };
    let mut state = model.init_state(&init_hyper)?;

    let batch = model.info().batch;
    let mut curves = vec![];
    let mut best_val = f64::INFINITY;
    let mut best_epoch = 0usize;
    let mut test_at_best = f64::NAN;
    let mut step: u32 = 0;
    let mut stale = 0usize;

    let eval_hyper = Hyper {
        mode: opts.eval_mode(),
        dropout: 0.0,
        in_dropout: 0.0,
        ..Default::default()
    };

    for epoch in 0..opts.epochs {
        let t = Timer::start();
        let lr = opts.schedule.at(epoch);
        let mut pf =
            Prefetcher::spawn(&data.train, batch, Plan::Shuffled { seed: rng.next_u64() }, 3);
        let mut loss_sum = 0f64;
        let mut err_sum = 0f64;
        let mut seen = 0usize;
        while let Some(b) = pf.next() {
            step += 1;
            let hyper = Hyper {
                lr,
                mode: opts.mode,
                opt: opts.opt,
                momentum: opts.momentum,
                beta2: opts.beta2,
                eps: opts.eps,
                dropout: opts.dropout,
                in_dropout: opts.in_dropout,
                bn_momentum: opts.bn_momentum,
                lr_scale: opts.lr_scale,
                step,
                seed: (rng.next_u64() & 0xFF_FFFF) as u32,
            };
            let m = model.train_step(&mut state, &b.x, &b.y, &hyper)?;
            loss_sum += m.loss as f64 * b.n_valid as f64;
            err_sum += m.n_err as f64;
            seen += b.n_valid;
        }
        let train_loss = loss_sum / seen.max(1) as f64;
        let train_err = err_sum / seen.max(1) as f64;
        let train_seconds = t.elapsed_s();

        let (_, val_err) = evaluate(model, &state, &data.val, &eval_hyper)?;
        let rec = EpochRecord {
            epoch,
            lr,
            train_loss,
            train_err,
            val_err,
            seconds: t.elapsed_s(),
        };
        if opts.verbose {
            // train-phase throughput only (rec.seconds also covers the
            // validation pass)
            let steps_per_s = pf.n_batches as f64 / train_seconds.max(1e-9);
            eprintln!(
                "epoch {:>3}  lr {:.5}  train loss {:.4}  train err {:.4}  val err {:.4}  ({:.1}s, {:.0} steps/s)",
                epoch, lr, train_loss, train_err, val_err, rec.seconds, steps_per_s
            );
        }
        curves.push(rec);

        if val_err < best_val {
            best_val = val_err;
            best_epoch = epoch;
            stale = 0;
            // paper: report the test error associated with the best
            // validation error; evaluate it now so no snapshot is needed.
            let (_, te) = evaluate(model, &state, &data.test, &eval_hyper)?;
            test_at_best = te;
        } else {
            stale += 1;
            if opts.patience > 0 && stale >= opts.patience {
                if opts.verbose {
                    eprintln!("early stop at epoch {epoch} (patience {})", opts.patience);
                }
                break;
            }
        }
    }

    Ok(RunResult {
        curves,
        best_epoch,
        best_val_err: best_val,
        test_err: test_at_best,
        state,
        steps: step as usize,
        total_seconds: total.elapsed_s(),
    })
}

/// Aggregate of repeated runs with different seeds (Table 2 MNIST column:
/// "we repeat each experiment 6 times with different initializations").
pub struct TrialSummary {
    pub test_errs: Vec<f64>,
    pub mean: f64,
    pub std: f64,
    pub results: Vec<RunResult>,
}

pub fn trials(
    model: &dyn Executor,
    data: &SplitData,
    opts: &TrainOpts,
    n_trials: usize,
) -> Result<TrialSummary> {
    let mut results = vec![];
    for t in 0..n_trials {
        let mut o = opts.clone();
        o.seed = opts.seed.wrapping_add(1000 * t as u64 + 17);
        results.push(train(model, data, &o)?);
    }
    let test_errs: Vec<f64> = results.iter().map(|r| r.test_err).collect();
    let (mean, std) = mean_std(&test_errs);
    Ok(TrialSummary { test_errs, mean, std, results })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_mode_follows_paper_sec_2_6() {
        let mut o = TrainOpts::default();
        o.mode = Mode::Det;
        assert_eq!(o.eval_mode(), Mode::Det); // method 1: binary weights
        o.mode = Mode::Stoch;
        assert_eq!(o.eval_mode(), Mode::None); // method 2: real weights
        o.mode = Mode::None;
        assert_eq!(o.eval_mode(), Mode::None);
    }

    // End-to-end trainer tests require compiled artifacts; they live in
    // rust/tests/integration_trainer.rs.
}
