//! The training loop (Algorithm 1 driven at full-epoch granularity) and
//! multi-seed trial aggregation.
//!
//! Backend-agnostic: everything goes through the [`Executor`] trait, so
//! the same loop drives the pure-Rust reference backend and (with the
//! `pjrt` feature) the PJRT artifact path.
//!
//! # Crash-safe checkpointing
//!
//! All trainer stochasticity derives from one root [`Rng`]: one draw per
//! epoch (the shuffle seed) plus one draw per step (the per-step hyper
//! seed). Capturing the RNG stream state together with the
//! [`TrainState`] and the epoch/step counters at an epoch boundary is
//! therefore enough to make resuming *bit-exact*:
//!
//! ```text
//! train(N)  ==  train(k) + crash + resume + train(N-k)      (bitwise)
//! ```
//!
//! for every optimizer and binarization mode. The contract is pinned by
//! rust/tests/checkpoint_train.rs and exercised under injected faults by
//! rust/tests/chaos_train.rs.
//!
//! The same epoch-boundary snapshot doubles as the divergence-recovery
//! point: when more than `max_diverged_steps` non-finite steps hit within
//! one epoch, the trainer rolls the run back to the last boundary and
//! replays (the fault-injection trial counters keep advancing, so an
//! injected-fault replay is decorrelated from the first attempt).

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::data::SplitData;
use crate::pipeline::{Plan, Prefetcher};
use crate::runtime::{Executor, Hyper, Mode, Opt, TrainState};
use crate::stats::mean_std;
use crate::util::checkpoint::{self, Checkpoint, CurvePoint};
use crate::util::error::Result;
use crate::util::{crc32, FaultPlan, Rng, Timer};
use crate::{anyhow, ensure};

use super::schedule::LrSchedule;

/// Where to resume a checkpointed run from.
#[derive(Clone, Debug)]
pub enum ResumeFrom {
    /// newest loadable checkpoint in `CheckpointOpts::dir` (a torn or
    /// corrupt newest file falls back to the previous good one; an empty
    /// directory starts fresh)
    Latest,
    /// an explicit checkpoint file; any load failure is a hard error
    Path(PathBuf),
}

/// Checkpointing knobs for one run.
#[derive(Clone, Debug)]
pub struct CheckpointOpts {
    /// directory for `ckpt-NNNNNN.bcckpt` files (`None` = no on-disk
    /// checkpoints)
    pub dir: Option<PathBuf>,
    /// save cadence in epochs (the final epoch always saves)
    pub every_epochs: usize,
    /// retain only the newest N checkpoint files (0 = keep all)
    pub keep: usize,
    pub resume: Option<ResumeFrom>,
}

impl Default for CheckpointOpts {
    fn default() -> Self {
        Self { dir: None, every_epochs: 1, keep: 3, resume: None }
    }
}

/// Everything one training run needs (one Table-1/Table-2 cell).
#[derive(Clone, Debug)]
pub struct TrainOpts {
    pub epochs: usize,
    pub schedule: LrSchedule,
    pub mode: Mode,
    pub opt: Opt,
    pub momentum: f32,
    pub beta2: f32,
    pub eps: f32,
    pub dropout: f32,
    pub in_dropout: f32,
    pub bn_momentum: f32,
    pub lr_scale: bool,
    pub seed: u64,
    /// early-stopping patience in epochs (0 = never stop early).
    pub patience: usize,
    /// print per-epoch progress lines.
    pub verbose: bool,
    /// override the Sec.-2.6 default test-time weight mode (e.g. evaluate
    /// a stochastically-trained net by sampling w_b — alternative 3 —
    /// which keeps the BN statistics calibrated at short training).
    pub eval_override: Option<Mode>,
    /// checkpoint/resume configuration.
    pub checkpoint: CheckpointOpts,
    /// roll back to the last epoch-boundary snapshot once more than this
    /// many steps diverge since that snapshot (0 = never roll back).
    pub max_diverged_steps: usize,
    /// skip the weight/BN update on steps whose loss or gradients go
    /// non-finite, leaving the state bit-identical (divergence sentinel).
    pub skip_diverged: bool,
    /// fault-injection plan (chaos tests / BCRUN_FAULTS); shared with the
    /// executor so step panics, torn saves and gradient poison all draw
    /// from one deterministic plan.
    pub faults: Option<Arc<FaultPlan>>,
    /// cooperative stop latch (SIGTERM): when set, the trainer writes a
    /// final checkpoint at the next epoch boundary and returns with
    /// `RunResult::interrupted`.
    pub stop: Option<Arc<AtomicBool>>,
}

impl Default for TrainOpts {
    fn default() -> Self {
        Self {
            epochs: 20,
            schedule: LrSchedule::Exponential { start: 0.02, end: 0.002, epochs: 20 },
            mode: Mode::Det,
            opt: Opt::Sgd,
            momentum: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            dropout: 0.0,
            in_dropout: 0.0,
            bn_momentum: 0.9,
            lr_scale: true,
            seed: 1,
            patience: 0,
            verbose: false,
            eval_override: None,
            checkpoint: CheckpointOpts::default(),
            max_diverged_steps: 0,
            skip_diverged: true,
            faults: None,
            stop: None,
        }
    }
}

impl TrainOpts {
    /// Test-time inference mode per paper Sec. 2.6: deterministic BC uses
    /// the binary weights (method 1); stochastic BC and the baselines use
    /// the real-valued weights (method 2). `eval_override` selects
    /// alternative 3 (stochastic sampling) or any other mode explicitly.
    pub fn eval_mode(&self) -> Mode {
        if let Some(m) = self.eval_override {
            return m;
        }
        match self.mode {
            Mode::Det => Mode::Det,
            _ => Mode::None,
        }
    }

    /// CRC32 fingerprint over the hyperparameters that shape the training
    /// stream but have no dedicated checkpoint field. Resume compares
    /// fingerprints and refuses on mismatch — a run resumed under
    /// different knobs would silently diverge from the uninterrupted one.
    /// Output-only knobs (`verbose`) and the checkpoint/rollback policy
    /// itself are deliberately excluded; `skip_diverged` is included
    /// because a skipped vs. applied update changes the state stream.
    pub fn hyper_fingerprint(&self) -> u32 {
        let mut b: Vec<u8> = Vec::with_capacity(64);
        match self.schedule {
            LrSchedule::Constant { lr } => {
                b.push(0);
                b.extend_from_slice(&lr.to_bits().to_le_bytes());
            }
            LrSchedule::Exponential { start, end, epochs } => {
                b.push(1);
                b.extend_from_slice(&start.to_bits().to_le_bytes());
                b.extend_from_slice(&end.to_bits().to_le_bytes());
                b.extend_from_slice(&(epochs as u64).to_le_bytes());
            }
        }
        for f in [
            self.momentum,
            self.beta2,
            self.eps,
            self.dropout,
            self.in_dropout,
            self.bn_momentum,
        ] {
            b.extend_from_slice(&f.to_bits().to_le_bytes());
        }
        b.push(self.lr_scale as u8);
        b.extend_from_slice(&(self.patience as u64).to_le_bytes());
        b.push(self.eval_override.map_or(255, |m| m as u8));
        b.push(self.skip_diverged as u8);
        crc32(&b)
    }
}

/// Per-epoch curve record (Figure 3's series).
#[derive(Clone, Copy, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub lr: f32,
    pub train_loss: f64,
    pub train_err: f64,
    pub val_err: f64,
    pub seconds: f64,
}

fn point_of(r: &EpochRecord) -> CurvePoint {
    CurvePoint {
        epoch: r.epoch as u32,
        lr: r.lr,
        train_loss: r.train_loss,
        train_err: r.train_err,
        val_err: r.val_err,
        seconds: r.seconds,
    }
}

fn record_of(c: &CurvePoint) -> EpochRecord {
    EpochRecord {
        epoch: c.epoch as usize,
        lr: c.lr,
        train_loss: c.train_loss,
        train_err: c.train_err,
        val_err: c.val_err,
        seconds: c.seconds,
    }
}

/// Outcome of one run.
#[derive(Debug)]
pub struct RunResult {
    pub curves: Vec<EpochRecord>,
    pub best_epoch: usize,
    pub best_val_err: f64,
    /// test error at the best-validation epoch (paper protocol).
    pub test_err: f64,
    pub state: TrainState,
    pub steps: usize,
    pub total_seconds: f64,
    /// lifetime count of steps the divergence sentinel flagged.
    pub diverged_steps: u64,
    /// how many times the run rolled back to the last snapshot.
    pub rollbacks: usize,
    /// the stop latch fired; the run checkpointed and returned early.
    pub interrupted: bool,
}

/// Train-phase throughput, guarded so a zero/near-zero or non-finite
/// elapsed time can never put an `inf`/`NaN` into logs or records.
pub fn steps_per_sec(n_batches: usize, seconds: f64) -> f64 {
    if seconds.is_finite() && seconds > 1e-9 {
        n_batches as f64 / seconds
    } else {
        0.0
    }
}

/// Everything `train` mutates across an epoch — the exact set a
/// [`Checkpoint`] captures and [`TrainerCore::restore`] reinstates.
struct TrainerCore {
    rng: Rng,
    state: TrainState,
    /// next epoch to run == number of completed epochs
    epoch: usize,
    step: u32,
    curves: Vec<EpochRecord>,
    best_val: f64,
    best_epoch: usize,
    test_at_best: f64,
    stale: usize,
    diverged_total: u64,
}

impl TrainerCore {
    fn fresh(seed: u64) -> TrainerCore {
        TrainerCore {
            rng: Rng::new(seed),
            state: TrainState::default(),
            epoch: 0,
            step: 0,
            curves: vec![],
            best_val: f64::INFINITY,
            best_epoch: 0,
            test_at_best: f64::NAN,
            stale: 0,
            diverged_total: 0,
        }
    }

    fn to_checkpoint(&self, opts: &TrainOpts, model: &str, hyper_fp: u32) -> Checkpoint {
        Checkpoint {
            model: model.to_string(),
            mode: opts.mode as u8,
            opt: opts.opt as u8,
            seed: opts.seed,
            total_epochs: opts.epochs as u32,
            hyper_fp,
            epoch_next: self.epoch as u32,
            step: self.step,
            rng: self.rng.state(),
            best_val: self.best_val,
            best_epoch: self.best_epoch as u32,
            test_at_best: self.test_at_best,
            stale: self.stale as u32,
            diverged_steps: self.diverged_total,
            curves: self.curves.iter().map(point_of).collect(),
            state: self.state.snapshot(),
        }
    }

    fn restore(&mut self, ck: &Checkpoint) {
        self.rng = Rng::from_state(ck.rng);
        self.state = ck.state.snapshot();
        self.epoch = ck.epoch_next as usize;
        self.step = ck.step;
        self.curves = ck.curves.iter().map(record_of).collect();
        self.best_val = ck.best_val;
        self.best_epoch = ck.best_epoch as usize;
        self.test_at_best = ck.test_at_best;
        self.stale = ck.stale as usize;
        self.diverged_total = ck.diverged_steps;
    }
}

/// Refuse to resume a checkpoint written under a different configuration:
/// the replayed stream would silently diverge from the uninterrupted run.
fn check_resume_compat(
    ck: &Checkpoint,
    model: &str,
    opts: &TrainOpts,
    hyper_fp: u32,
) -> Result<()> {
    ensure!(
        ck.model == model,
        "checkpoint is for model '{}', this run drives '{model}'",
        ck.model
    );
    ensure!(
        ck.mode == opts.mode as u8,
        "checkpoint mode {} != run mode {}",
        ck.mode,
        opts.mode as u8
    );
    ensure!(
        ck.opt == opts.opt as u8,
        "checkpoint optimizer {} != run optimizer {}",
        ck.opt,
        opts.opt as u8
    );
    ensure!(ck.seed == opts.seed, "checkpoint seed {} != run seed {}", ck.seed, opts.seed);
    ensure!(
        ck.total_epochs as usize == opts.epochs,
        "checkpoint targets {} epochs, run targets {}",
        ck.total_epochs,
        opts.epochs
    );
    ensure!(
        ck.hyper_fp == hyper_fp,
        "checkpoint hyperparameter fingerprint {:#010x} != run fingerprint {hyper_fp:#010x}",
        ck.hyper_fp
    );
    ensure!(
        ck.epoch_next as usize <= opts.epochs,
        "checkpoint has {} completed epochs, past the run's {} epoch target",
        ck.epoch_next,
        opts.epochs
    );
    Ok(())
}

/// Evaluate a dataset (padded batching), masked to valid examples.
pub fn evaluate(
    model: &dyn Executor,
    state: &TrainState,
    ds: &crate::data::Dataset,
    hyper: &Hyper,
) -> Result<(f64, f64)> {
    let batch = model.info().batch;
    let mut pf = Prefetcher::spawn(ds, batch, Plan::Sequential, 2);
    let mut loss_sum = 0f64;
    let mut err_sum = 0f64;
    let mut n = 0usize;
    while let Some(b) = pf.next() {
        let (lossv, errv) = model.eval_batch(state, &b.x, &b.y, hyper)?;
        for i in 0..b.n_valid {
            loss_sum += lossv[i] as f64;
            err_sum += errv[i] as f64;
        }
        n += b.n_valid;
    }
    let n = n.max(1) as f64;
    Ok((loss_sum / n, err_sum / n))
}

/// Hard cap on divergence rollbacks per run: a state that keeps
/// re-diverging after this many replays is not going to converge, and
/// every replay re-spends a full epoch of compute.
const MAX_ROLLBACKS: usize = 8;

/// Train one model per the paper's protocol.
pub fn train(model: &dyn Executor, data: &SplitData, opts: &TrainOpts) -> Result<RunResult> {
    let total = Timer::start();
    let info = model.info();
    let batch = info.batch;
    let hyper_fp = opts.hyper_fingerprint();
    let faults = opts.faults.as_deref();

    let mut core = TrainerCore::fresh(opts.seed);
    let mut resumed = false;
    if let Some(resume) = &opts.checkpoint.resume {
        let loaded = match resume {
            ResumeFrom::Latest => {
                let dir = opts.checkpoint.dir.as_ref().ok_or_else(|| {
                    anyhow!("resume from the latest checkpoint requires a checkpoint dir")
                })?;
                checkpoint::latest_good(dir)
            }
            ResumeFrom::Path(p) => Some((p.clone(), checkpoint::load(p)?)),
        };
        match loaded {
            Some((path, ck)) => {
                check_resume_compat(&ck, &info.name, opts, hyper_fp)?;
                ck.state.validate_against(info)?;
                core.restore(&ck);
                resumed = true;
                if opts.verbose {
                    eprintln!(
                        "resumed from {} ({} epochs done, step {})",
                        path.display(),
                        core.epoch,
                        core.step
                    );
                }
            }
            None => {
                if opts.verbose {
                    eprintln!("no usable checkpoint found; starting fresh");
                }
            }
        }
    }
    if !resumed {
        let init_hyper = Hyper { seed: (opts.seed & 0xFF_FFFF) as u32, ..Default::default() };
        core.state = model.init_state(&init_hyper)?;
    }

    let eval_hyper = Hyper {
        mode: opts.eval_mode(),
        dropout: 0.0,
        in_dropout: 0.0,
        ..Default::default()
    };

    // Epoch-boundary snapshot: the divergence-rollback target, and (when
    // a checkpoint dir is set) the bytes that go to disk. Skipped
    // entirely when neither feature is on, so the plain path pays no
    // state-clone overhead.
    let want_snapshots = opts.checkpoint.dir.is_some() || opts.max_diverged_steps > 0;
    let mut snapshot: Option<Checkpoint> =
        want_snapshots.then(|| core.to_checkpoint(opts, &info.name, hyper_fp));

    let every = opts.checkpoint.every_epochs.max(1);
    let mut rollbacks = 0usize;
    let mut diverged_recent = 0usize;
    let mut interrupted = false;

    'epochs: while core.epoch < opts.epochs {
        let t = Timer::start();
        let lr = opts.schedule.at(core.epoch);
        let mut pf =
            Prefetcher::spawn(&data.train, batch, Plan::Shuffled { seed: core.rng.next_u64() }, 3);
        let n_batches = pf.n_batches;
        let mut loss_sum = 0f64;
        let mut err_sum = 0f64;
        let mut seen = 0usize;
        let mut rollback_now = false;
        while let Some(b) = pf.next() {
            if let Some(f) = faults {
                f.maybe_panic_step();
            }
            core.step += 1;
            let hyper = Hyper {
                lr,
                mode: opts.mode,
                opt: opts.opt,
                momentum: opts.momentum,
                beta2: opts.beta2,
                eps: opts.eps,
                dropout: opts.dropout,
                in_dropout: opts.in_dropout,
                bn_momentum: opts.bn_momentum,
                lr_scale: opts.lr_scale,
                step: core.step,
                seed: (core.rng.next_u64() & 0xFF_FFFF) as u32,
                skip_nonfinite: opts.skip_diverged,
            };
            let m = model.train_step(&mut core.state, &b.x, &b.y, &hyper)?;
            if m.diverged {
                // a diverged step contributes no metrics: its loss is
                // non-finite and (when skipping) its update never landed
                core.diverged_total += 1;
                diverged_recent += 1;
                if opts.verbose {
                    eprintln!(
                        "step {}: non-finite loss/gradient{}",
                        core.step,
                        if opts.skip_diverged { " (update skipped)" } else { "" }
                    );
                }
                if opts.max_diverged_steps > 0 && diverged_recent > opts.max_diverged_steps {
                    rollback_now = true;
                    break;
                }
            } else {
                loss_sum += m.loss as f64 * b.n_valid as f64;
                err_sum += m.n_err as f64;
                seen += b.n_valid;
            }
        }
        if rollback_now {
            rollbacks += 1;
            ensure!(
                rollbacks <= MAX_ROLLBACKS,
                "training diverged past {} steps on {rollbacks} rollback attempts; giving up",
                opts.max_diverged_steps
            );
            let ck = snapshot
                .as_ref()
                .ok_or_else(|| anyhow!("rollback requested but no snapshot was captured"))?;
            if opts.verbose {
                eprintln!(
                    "divergence: rolling back to the epoch-{} boundary (rollback {rollbacks})",
                    ck.epoch_next
                );
            }
            core.restore(ck);
            diverged_recent = 0;
            continue 'epochs;
        }

        let train_loss = loss_sum / seen.max(1) as f64;
        let train_err = err_sum / seen.max(1) as f64;
        let train_seconds = t.elapsed_s();

        let (_, val_err) = evaluate(model, &core.state, &data.val, &eval_hyper)?;
        let rec = EpochRecord {
            epoch: core.epoch,
            lr,
            train_loss,
            train_err,
            val_err,
            seconds: t.elapsed_s(),
        };
        if opts.verbose {
            // train-phase throughput only (rec.seconds also covers the
            // validation pass)
            eprintln!(
                "epoch {:>3}  lr {:.5}  train loss {:.4}  train err {:.4}  val err {:.4}  ({:.1}s, {:.0} steps/s)",
                core.epoch, lr, train_loss, train_err, val_err, rec.seconds,
                steps_per_sec(n_batches, train_seconds)
            );
        }
        core.curves.push(rec);

        let mut early_stop = false;
        if val_err < core.best_val {
            core.best_val = val_err;
            core.best_epoch = core.epoch;
            core.stale = 0;
            // paper: report the test error associated with the best
            // validation error; evaluate it now so no snapshot is needed.
            let (_, te) = evaluate(model, &core.state, &data.test, &eval_hyper)?;
            core.test_at_best = te;
        } else {
            core.stale += 1;
            if opts.patience > 0 && core.stale >= opts.patience {
                early_stop = true;
            }
        }

        core.epoch += 1;
        let stop_req = opts.stop.as_ref().is_some_and(|s| s.load(Ordering::SeqCst));

        if want_snapshots {
            let ck = core.to_checkpoint(opts, &info.name, hyper_fp);
            if let Some(dir) = &opts.checkpoint.dir {
                if core.epoch % every == 0 || core.epoch == opts.epochs || stop_req {
                    let path = checkpoint::save_into_dir(dir, &ck, opts.checkpoint.keep, faults)?;
                    if opts.verbose {
                        eprintln!("checkpoint: wrote {}", path.display());
                    }
                }
            }
            snapshot = Some(ck);
            diverged_recent = 0;
        }

        if stop_req {
            interrupted = true;
            if opts.verbose {
                eprintln!("stop requested; exiting after {} epochs (resumable)", core.epoch);
            }
            break;
        }
        if early_stop {
            if opts.verbose {
                eprintln!("early stop at epoch {} (patience {})", core.epoch - 1, opts.patience);
            }
            break;
        }
    }

    Ok(RunResult {
        curves: core.curves,
        best_epoch: core.best_epoch,
        best_val_err: core.best_val,
        test_err: core.test_at_best,
        state: core.state,
        steps: core.step as usize,
        total_seconds: total.elapsed_s(),
        diverged_steps: core.diverged_total,
        rollbacks,
        interrupted,
    })
}

/// Aggregate of repeated runs with different seeds (Table 2 MNIST column:
/// "we repeat each experiment 6 times with different initializations").
pub struct TrialSummary {
    pub test_errs: Vec<f64>,
    pub mean: f64,
    pub std: f64,
    pub results: Vec<RunResult>,
}

pub fn trials(
    model: &dyn Executor,
    data: &SplitData,
    opts: &TrainOpts,
    n_trials: usize,
) -> Result<TrialSummary> {
    let mut results = vec![];
    for t in 0..n_trials {
        let mut o = opts.clone();
        o.seed = opts.seed.wrapping_add(1000 * t as u64 + 17);
        results.push(train(model, data, &o)?);
    }
    let test_errs: Vec<f64> = results.iter().map(|r| r.test_err).collect();
    let (mean, std) = mean_std(&test_errs);
    Ok(TrialSummary { test_errs, mean, std, results })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_mode_follows_paper_sec_2_6() {
        let mut o = TrainOpts::default();
        o.mode = Mode::Det;
        assert_eq!(o.eval_mode(), Mode::Det); // method 1: binary weights
        o.mode = Mode::Stoch;
        assert_eq!(o.eval_mode(), Mode::None); // method 2: real weights
        o.mode = Mode::None;
        assert_eq!(o.eval_mode(), Mode::None);
    }

    #[test]
    fn steps_per_sec_never_produces_nonfinite() {
        assert_eq!(steps_per_sec(100, 0.0), 0.0);
        assert_eq!(steps_per_sec(100, -1.0), 0.0);
        assert_eq!(steps_per_sec(100, 1e-12), 0.0);
        assert_eq!(steps_per_sec(100, f64::NAN), 0.0);
        assert_eq!(steps_per_sec(100, f64::INFINITY), 0.0);
        assert!((steps_per_sec(100, 2.0) - 50.0).abs() < 1e-12);
        assert_eq!(steps_per_sec(0, 1.0), 0.0);
    }

    #[test]
    fn fingerprint_tracks_stream_shaping_knobs_only() {
        let base = TrainOpts::default();
        let fp = base.hyper_fingerprint();
        // stable across calls
        assert_eq!(fp, base.hyper_fingerprint());

        let mut o = base.clone();
        o.dropout = 0.5;
        assert_ne!(fp, o.hyper_fingerprint(), "dropout must change the fingerprint");

        let mut o = base.clone();
        o.schedule = LrSchedule::Constant { lr: 0.02 };
        assert_ne!(fp, o.hyper_fingerprint(), "schedule shape must change the fingerprint");

        let mut o = base.clone();
        o.eval_override = Some(Mode::Stoch);
        assert_ne!(fp, o.hyper_fingerprint(), "eval override must change the fingerprint");

        let mut o = base.clone();
        o.skip_diverged = !o.skip_diverged;
        assert_ne!(fp, o.hyper_fingerprint(), "skip policy must change the fingerprint");

        // output-only / recovery-policy knobs do not participate
        let mut o = base.clone();
        o.verbose = true;
        o.max_diverged_steps = 5;
        o.checkpoint.keep = 99;
        assert_eq!(fp, o.hyper_fingerprint());
    }

    #[test]
    fn resume_compat_rejects_mismatches() {
        let opts = TrainOpts::default();
        let fp = opts.hyper_fingerprint();
        let core = TrainerCore::fresh(opts.seed);
        let ck = core.to_checkpoint(&opts, "mlp", fp);

        assert!(check_resume_compat(&ck, "mlp", &opts, fp).is_ok());
        assert!(check_resume_compat(&ck, "cnn", &opts, fp).is_err());
        assert!(check_resume_compat(&ck, "mlp", &opts, fp ^ 1).is_err());

        let mut o = opts.clone();
        o.opt = Opt::Adam;
        assert!(check_resume_compat(&ck, "mlp", &o, fp).is_err());

        let mut o = opts.clone();
        o.seed += 1;
        assert!(check_resume_compat(&ck, "mlp", &o, fp).is_err());

        let mut o = opts.clone();
        o.epochs += 1;
        assert!(check_resume_compat(&ck, "mlp", &o, fp).is_err());
    }

    #[test]
    fn core_checkpoint_restore_is_lossless() {
        let opts = TrainOpts::default();
        let fp = opts.hyper_fingerprint();
        let mut core = TrainerCore::fresh(9);
        for _ in 0..13 {
            core.rng.next_u64();
        }
        core.epoch = 3;
        core.step = 21;
        core.best_val = 0.125;
        core.best_epoch = 2;
        core.test_at_best = 0.25;
        core.stale = 1;
        core.diverged_total = 4;
        core.curves = (0..3)
            .map(|e| EpochRecord {
                epoch: e,
                lr: 0.01,
                train_loss: 0.5,
                train_err: 0.2,
                val_err: 0.3,
                seconds: 1.0,
            })
            .collect();
        core.state = TrainState {
            params: vec![vec![1.0, -0.5]],
            m: vec![vec![0.1, 0.2]],
            v: vec![vec![0.0, 0.0]],
        };
        let next = core.rng.clone().next_u64();

        let ck = core.to_checkpoint(&opts, "toy", fp);
        let mut other = TrainerCore::fresh(1);
        other.restore(&ck);
        assert_eq!(other.epoch, 3);
        assert_eq!(other.step, 21);
        assert_eq!(other.stale, 1);
        assert_eq!(other.diverged_total, 4);
        assert_eq!(other.best_epoch, 2);
        assert_eq!(other.best_val.to_bits(), core.best_val.to_bits());
        assert_eq!(other.curves.len(), 3);
        assert_eq!(other.state.params, core.state.params);
        assert_eq!(other.rng.next_u64(), next, "RNG stream must continue identically");
    }

    // End-to-end trainer tests (bit-exact resume matrix, chaos runs)
    // live in rust/tests/checkpoint_train.rs and rust/tests/chaos_train.rs.
}
