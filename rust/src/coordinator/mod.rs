//! The experiment coordinator: the paper's training protocol as a library.
//!
//! Implements Sec. 3's procedure: shuffled minibatch SGD with an
//! exponentially decaying learning rate, per-epoch validation, model
//! selection on the best validation error, and reporting the test error
//! associated with that epoch (no retraining on the validation set).
//! Multi-seed trials aggregate to Table 2's "mean ± std" entries.

pub mod protocol;
pub mod schedule;
pub mod trainer;

pub use protocol::{cnn_opts, dropout_opts, mnist_opts, prepare, DataOpts};
pub use schedule::LrSchedule;
pub use trainer::{
    steps_per_sec, train, trials, CheckpointOpts, EpochRecord, ResumeFrom, RunResult, TrainOpts,
    TrialSummary,
};
