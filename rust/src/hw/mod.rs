//! Hardware cost model: the paper's efficiency arithmetic, made explicit.
//!
//! Sec. 1/5 claims: (a) BinaryConnect removes the multiplications from the
//! forward and backward propagations — about 2/3 of all training
//! multiplications — enabling ~3x specialized-hardware training speedups;
//! (b) at test time, deterministic BC removes multiplications entirely
//! from the weight inner loops and cuts weight memory >= 16x (32x vs f32).
//!
//! We count multiply and accumulate operations per training step from the
//! model's parameter spec, exactly as a hardware designer would budget a
//! datapath, and reproduce the claimed ratios in `benches/hw_claims.rs`.

use crate::runtime::manifest::ParamInfo;

/// Multiply / accumulate counts for one phase.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OpCount {
    pub mults: u64,
    pub adds: u64,
}

impl OpCount {
    fn add(&mut self, o: OpCount) {
        self.mults += o.mults;
        self.adds += o.adds;
    }
}

/// Per-step op counts, by back-propagation phase (paper Sec. 2.3's three
/// steps).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepCost {
    /// 1. forward propagation
    pub forward: OpCount,
    /// 2. backward propagation (gradients w.r.t. activations)
    pub backward: OpCount,
    /// 3. parameter gradients + update
    pub update: OpCount,
}

impl StepCost {
    pub fn total_mults(&self) -> u64 {
        self.forward.mults + self.backward.mults + self.update.mults
    }

    pub fn total_adds(&self) -> u64 {
        self.forward.adds + self.backward.adds + self.update.adds
    }
}

/// MACs of a weight tensor applied to a batch: dense (k,n) -> batch*k*n,
/// conv (kh,kw,cin,cout) at spatial hw -> batch*hw*hw*kh*kw*cin*cout.
/// `spatial` carries the output H*W per conv layer (1 for dense).
fn layer_macs(p: &ParamInfo, batch: u64, spatial: u64) -> u64 {
    let numel: u64 = p.shape.iter().map(|&d| d as u64).product();
    batch * spatial * numel
}

/// Estimate per-step op counts for a model spec.
///
/// `spatial_of` maps a weight param's name to its output spatial size
/// (H*W); dense layers return 1. `binary` selects BinaryConnect (weights
/// are ±1 during propagations) versus a conventional real-weight net.
pub fn step_cost<F: Fn(&str) -> u64>(
    params: &[ParamInfo],
    batch: u64,
    binary: bool,
    spatial_of: F,
) -> StepCost {
    let mut cost = StepCost::default();
    for p in params {
        match p.kind.as_str() {
            "weight" => {
                let macs = layer_macs(p, batch, spatial_of(&p.name));
                let numel: u64 = p.shape.iter().map(|&d| d as u64).product();
                // 1. forward: x @ w_b — binary weights need no multiplies
                cost.forward.add(OpCount {
                    mults: if binary { 0 } else { macs },
                    adds: macs,
                });
                // 2. backward: g @ w_b^T — same shape, same saving
                cost.backward.add(OpCount {
                    mults: if binary { 0 } else { macs },
                    adds: macs,
                });
                // 3. parameter gradient dW = a^T g: real x real — the
                //    multiplications BinaryConnect does NOT remove — plus
                //    the update arithmetic itself.
                cost.update.add(OpCount { mults: macs + numel, adds: macs + numel });
            }
            "affine" => {
                let numel: u64 = p.shape.iter().map(|&d| d as u64).product();
                // BN affine fwd/bwd + its update: one mult/add per element
                // per example (tiny next to the GEMMs, counted for honesty)
                cost.forward.add(OpCount { mults: batch * numel, adds: batch * numel });
                cost.backward.add(OpCount { mults: batch * numel, adds: batch * numel });
                cost.update.add(OpCount { mults: numel, adds: numel });
            }
            _ => {} // bn_stat: no arithmetic in the datapath model
        }
    }
    cost
}

/// The headline ratio: fraction of multiplications removed by BC.
pub fn mult_reduction(real: &StepCost, bc: &StepCost) -> f64 {
    1.0 - bc.total_mults() as f64 / real.total_mults() as f64
}

/// Memory model for test-time weights.
#[derive(Clone, Copy, Debug)]
pub struct MemoryModel {
    pub f32_bytes: u64,
    pub f16_bytes: u64,
    pub packed_bytes: u64,
}

pub fn weight_memory(params: &[ParamInfo]) -> MemoryModel {
    let scalars: u64 = params
        .iter()
        .filter(|p| p.kind == "weight")
        .map(|p| p.shape.iter().map(|&d| d as u64).product::<u64>())
        .sum();
    MemoryModel {
        f32_bytes: scalars * 4,
        f16_bytes: scalars * 2,
        packed_bytes: scalars.div_ceil(8),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dense(name: &str, k: usize, n: usize) -> ParamInfo {
        ParamInfo { name: name.into(), shape: vec![k, n], kind: "weight".into(), glorot: 0.1 }
    }

    fn affine(name: &str, n: usize) -> ParamInfo {
        ParamInfo { name: name.into(), shape: vec![n], kind: "affine".into(), glorot: 0.0 }
    }

    fn stat(name: &str, n: usize) -> ParamInfo {
        ParamInfo { name: name.into(), shape: vec![n], kind: "bn_stat".into(), glorot: 0.0 }
    }

    #[test]
    fn pure_dense_net_reduction_approaches_two_thirds() {
        // With only GEMMs (the asymptotic case the paper cites), fwd and
        // bwd multiplications vanish: reduction -> 2/3 as layers grow.
        let params = vec![dense("l0", 1024, 1024), dense("l1", 1024, 1024)];
        let real = step_cost(&params, 100, false, |_| 1);
        let bc = step_cost(&params, 100, true, |_| 1);
        let red = mult_reduction(&real, &bc);
        assert!((red - 2.0 / 3.0).abs() < 0.01, "reduction = {red}");
    }

    #[test]
    fn bn_affine_shrinks_reduction_slightly() {
        let params = vec![dense("l0", 256, 256), affine("bn.g", 256), stat("bn.m", 256)];
        let real = step_cost(&params, 64, false, |_| 1);
        let bc = step_cost(&params, 64, true, |_| 1);
        let red = mult_reduction(&real, &bc);
        assert!(red > 0.6 && red < 2.0 / 3.0, "reduction = {red}");
    }

    #[test]
    fn conv_spatial_multiplier_counts() {
        let conv = ParamInfo {
            name: "conv0.W".into(),
            shape: vec![3, 3, 3, 16],
            kind: "weight".into(),
            glorot: 0.1,
        };
        let c = step_cost(&[conv], 2, false, |_| 32 * 32);
        // fwd MACs = batch * spatial * numel = 2*1024*432
        assert_eq!(c.forward.mults, 2 * 1024 * 432);
    }

    #[test]
    fn adds_survive_binarization() {
        let params = vec![dense("l0", 128, 128)];
        let real = step_cost(&params, 10, false, |_| 1);
        let bc = step_cost(&params, 10, true, |_| 1);
        assert_eq!(real.total_adds(), bc.total_adds());
        assert!(bc.forward.mults == 0 && bc.backward.mults == 0);
        assert!(bc.update.mults > 0); // the remaining third
    }

    #[test]
    fn cost_model_counts_pin_the_shared_conv_spatial_schedule() {
        // `bcrun hw` resolves each conv weight's output spatial size via
        // conv::spatial_dims — pin the resulting counts so a schedule
        // change (pool placement, padding) shows up as a test diff here,
        // not as a silently different table.
        let info = crate::runtime::reference::cnn_info("cnn", 16, 64, 1);
        let dims = crate::conv::spatial_dims(&info).unwrap();
        let hw_of = |name: &str| -> u64 {
            dims.iter().find(|d| d.name == name).map(|d| d.spatial() as u64).unwrap_or(1)
        };
        // conv MAC ledger by hand: SAME conv at 32,32,16,16,8,8 spatial
        // with 3x3 kernels and 3->16->16->32->32->64->64 channels
        let spatial = [32u64 * 32, 32 * 32, 16 * 16, 16 * 16, 8 * 8, 8 * 8];
        let chans = [(3u64, 16u64), (16, 16), (16, 32), (32, 32), (32, 64), (64, 64)];
        let conv_macs: u64 = spatial
            .iter()
            .zip(&chans)
            .map(|(s, &(cin, cout))| s * 9 * cin * cout)
            .sum();
        // dense MACs: flatten 4*4*64 -> 64 -> 64 -> 10
        let dense_macs: u64 = (4 * 4 * 64) * 64 + 64 * 64 + 64 * 10;
        let real = step_cost(&info.params, 1, false, hw_of);
        let bc = step_cost(&info.params, 1, true, hw_of);
        assert_eq!(real.forward.mults, conv_macs + dense_macs + affine_elems(&info));
        // binarization removes exactly the weight-GEMM multiplies from
        // the forward pass; the BN affine multiplies survive
        assert_eq!(bc.forward.mults, affine_elems(&info));
        assert_eq!(real.forward.adds, bc.forward.adds);
    }

    fn affine_elems(info: &crate::runtime::manifest::ModelInfo) -> u64 {
        info.params
            .iter()
            .filter(|p| p.kind == "affine")
            .map(|p| p.shape.iter().map(|&d| d as u64).product::<u64>())
            .sum()
    }

    #[test]
    fn memory_model_ratios() {
        let params = vec![dense("l0", 1024, 1024), affine("b", 1024)];
        let m = weight_memory(&params);
        assert_eq!(m.f32_bytes / m.packed_bytes, 32);
        assert_eq!(m.f16_bytes / m.packed_bytes, 16); // the paper's "16x"
    }
}
